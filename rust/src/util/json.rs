//! Minimal JSON substrate (serde is unavailable offline).
//!
//! A complete recursive-descent parser + writer for the JSON subset we
//! exchange with the Python compile path (artifacts/manifest.json,
//! pruning specs, experiment reports). Numbers parse to f64; helpers
//! coerce to the integer types call-sites need.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: object field as usize (panics with a useful message).
    pub fn req_usize(&self, key: &str) -> usize {
        self.get(key)
            .and_then(Json::as_usize)
            .unwrap_or_else(|| panic!("json: missing usize field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> &str {
        self.get(key)
            .and_then(Json::as_str)
            .unwrap_or_else(|| panic!("json: missing str field `{key}`"))
    }

    pub fn usize_array(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(Json::as_usize).collect())
            .unwrap_or_default()
    }

    // ------------------------------------------------------- construction
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(v: &[String]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Str(x.clone())).collect())
    }

    // --------------------------------------------------------------- text
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.b.get(self.i) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected eof".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.i += 1; // opening quote
        let mut out = String::new();
        while let Some(&c) = self.b.get(self.i) {
            match c {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u".to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u".to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                _ => {
                    // copy a full utf-8 sequence
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    out.push_str(
                        std::str::from_utf8(&s[..len.min(s.len())])
                            .map_err(|_| "bad utf8".to_string())?,
                    );
                    self.i += len;
                }
            }
        }
        Err("unterminated string".into())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.i += 1;
        let mut v = Vec::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.b.get(self.i) {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.i += 1;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            if self.b.get(self.i) != Some(&b':') {
                return Err(format!("expected ':' at {}", self.i));
            }
            self.i += 1;
            m.insert(k, self.value()?);
            self.skip_ws();
            match self.b.get(self.i) {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "hi", "a": [1,2,3]}"#).unwrap();
        assert_eq!(v.req_usize("n"), 42);
        assert_eq!(v.req_str("s"), "hi");
        assert_eq!(v.get("a").unwrap().usize_array(), vec![1, 2, 3]);
    }

    #[test]
    fn nested_and_empty() {
        let v = Json::parse(r#"{"o": {}, "a": [], "deep": [[{"x": [0]}]]}"#).unwrap();
        assert!(v.get("o").unwrap().as_obj().unwrap().is_empty());
        assert_eq!(
            v.get("deep").unwrap().idx(0).unwrap().idx(0).unwrap()
                .get("x").unwrap().usize_array(),
            vec![0]
        );
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(txt) = std::fs::read_to_string(p) {
            let v = Json::parse(&txt).unwrap();
            assert!(v.get("models").is_some());
            assert!(v.get("artifacts").is_some());
        }
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("x", Json::arr_f64(&[1.5, 2.0])),
            ("y", Json::Str("s".into())),
        ]);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }
}
