//! Hand-rolled substrates for crates unavailable in the offline vendor
//! set (see DESIGN.md §4): RNG, JSON, CLI parsing, bench harness,
//! property testing, thread pool, and a tiny logger.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod threadpool;

use std::time::Instant;

/// Wall-clock scope timer used by the experiment drivers.
pub struct Timer {
    label: String,
    start: Instant,
}

impl Timer {
    pub fn new(label: &str) -> Timer {
        Timer { label: label.to_string(), start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        eprintln!("[time] {}: {:.2}s", self.label, self.secs());
    }
}

/// Leveled stderr logger (env: ZIPLM_LOG=debug|info|warn).
pub fn log_enabled(level: &str) -> bool {
    let cur = std::env::var("ZIPLM_LOG").unwrap_or_else(|_| "info".into());
    let rank = |l: &str| match l {
        "debug" => 0,
        "info" => 1,
        _ => 2,
    };
    rank(level) >= rank(&cur)
}

#[macro_export]
macro_rules! zlog {
    ($lvl:expr, $($arg:tt)*) => {
        if $crate::util::log_enabled($lvl) {
            eprintln!("[{}] {}", $lvl, format!($($arg)*));
        }
    };
}
