//! Micro-benchmark harness substrate (criterion is unavailable offline).
//!
//! criterion-style methodology on a budget: warmup, then timed batches
//! until a wall-clock budget is spent, reporting min/median/mean/p95
//! and a median-absolute-deviation noise estimate. `cargo bench`
//! targets use `harness = false` and drive this directly.
//!
//! [`JsonReport`] collects the per-benchmark stats and writes the
//! machine-readable `BENCH_hotpath.json` (flat `name → ns/iter`
//! median, with a `_meta` provenance object) that future PRs diff to
//! track the perf trajectory.

use std::path::Path;
use std::time::{Duration, Instant};

use super::json::Json;

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub mad_ns: f64,
}

impl Stats {
    pub fn line(&self) -> String {
        format!(
            "{:<48} {:>10} {:>12} {:>12} {:>12}  (n={})",
            self.name,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: Duration::from_millis(200), budget: Duration::from_secs(2), max_iters: 10_000 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { warmup: Duration::from_millis(50), budget: Duration::from_millis(500), max_iters: 2_000 }
    }

    /// Run `f` repeatedly, return timing stats. `f` should return some
    /// value; we black-box it to keep the optimizer honest.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Stats {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        let mut samples: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.budget && samples.len() < self.max_iters {
            let s = Instant::now();
            std::hint::black_box(f());
            samples.push(s.elapsed().as_nanos() as f64);
        }
        stats_from(name, &mut samples)
    }

    /// Time one already-running closure N times exactly (for expensive ops).
    pub fn run_n<T, F: FnMut() -> T>(&self, name: &str, n: usize, mut f: F) -> Stats {
        std::hint::black_box(f()); // single warmup
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            let s = Instant::now();
            std::hint::black_box(f());
            samples.push(s.elapsed().as_nanos() as f64);
        }
        stats_from(name, &mut samples)
    }
}

fn stats_from(name: &str, samples: &mut [f64]) -> Stats {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    let median = samples[n / 2];
    let mean = samples.iter().sum::<f64>() / n as f64;
    let p95 = samples[(n as f64 * 0.95) as usize % n];
    let mut dev: Vec<f64> = samples.iter().map(|x| (x - median).abs()).collect();
    dev.sort_by(|a, b| a.total_cmp(b));
    Stats {
        name: name.to_string(),
        iters: n,
        min_ns: samples[0],
        median_ns: median,
        mean_ns: mean,
        p95_ns: p95,
        mad_ns: dev[n / 2],
    }
}

/// Machine-readable bench output: a flat `name → median ns/iter` map
/// plus a `_meta` object (unit, harness, free-form notes). The flat
/// shape keeps `jq '."obs::scores native fc(128x512)"'`-style diffs
/// trivial across PRs.
#[derive(Default)]
pub struct JsonReport {
    entries: Vec<Stats>,
    notes: Vec<(String, String)>,
}

impl JsonReport {
    pub fn new() -> JsonReport {
        JsonReport::default()
    }

    /// Record a finished benchmark (call right after `Bench::run*`).
    pub fn push(&mut self, s: &Stats) {
        self.entries.push(s.clone());
    }

    /// Print the human-readable line AND record the stats — the one
    /// call every bench entry makes.
    pub fn record(&mut self, s: Stats) {
        println!("{}", s.line());
        self.entries.push(s);
    }

    /// Attach a provenance note to `_meta` (e.g. host, commit, caveat).
    pub fn note(&mut self, key: &str, value: &str) {
        self.notes.push((key.to_string(), value.to_string()));
    }

    pub fn to_json(&self) -> Json {
        let mut meta = vec![
            ("unit".to_string(), Json::Str("ns/iter (median)".into())),
            ("harness".to_string(), Json::Str("ziplm::util::bench".into())),
        ];
        for (k, v) in &self.notes {
            meta.push((k.clone(), Json::Str(v.clone())));
        }
        let mut map = std::collections::BTreeMap::new();
        map.insert("_meta".to_string(), Json::Obj(meta.into_iter().collect()));
        for s in &self.entries {
            map.insert(s.name.clone(), Json::Num(s.median_ns));
        }
        Json::Obj(map)
    }

    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_pretty() + "\n")
    }
}

pub fn header() -> String {
    format!(
        "{:<48} {:>10} {:>12} {:>12} {:>12}",
        "benchmark", "min", "median", "mean", "p95"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        let b = Bench { warmup: Duration::from_millis(1), budget: Duration::from_millis(20), max_iters: 1000 };
        let s = b.run("noop", || 1 + 1);
        assert!(s.iters > 10);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.p95_ns);
    }

    #[test]
    fn run_n_counts() {
        let b = Bench::quick();
        let s = b.run_n("n", 17, || std::hint::black_box(3u64.pow(7)));
        assert_eq!(s.iters, 17);
    }

    #[test]
    fn json_report_flat_name_to_ns() {
        let b = Bench::quick();
        let mut rep = JsonReport::new();
        rep.push(&b.run_n("fake::op", 3, || std::hint::black_box(2u64 * 21)));
        rep.note("host", "testbox");
        let j = rep.to_json();
        assert!(j.get("fake::op").and_then(crate::util::json::Json::as_f64).unwrap() >= 0.0);
        assert_eq!(
            j.get("_meta").and_then(|m| m.get("unit")).and_then(crate::util::json::Json::as_str),
            Some("ns/iter (median)")
        );
        // round-trips through the writer/parser
        let text = j.to_pretty();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert!(back.get("_meta").is_some());
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert!(fmt_ns(2_500.0).ends_with("us"));
        assert!(fmt_ns(2_500_000.0).ends_with("ms"));
        assert!(fmt_ns(2.5e9).ends_with('s'));
    }
}
