//! Seeded PRNG substrate (the `rand` crate is unavailable offline).
//!
//! SplitMix64 for stream splitting + xoshiro256** for generation: fast,
//! reproducible, and good enough statistically for data synthesis,
//! initialization and the SPDY mutation search. All experiment seeds
//! flow through here so every run in EXPERIMENTS.md is reproducible.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-layer / per-worker use).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // rejection-free (biased < 2^-32 for our n's; fine for synthesis)
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Sample from unnormalized weights.
    pub fn weighted(&mut self, w: &[f64]) -> usize {
        let total: f64 = w.iter().sum();
        let mut t = self.f64() * total;
        for (i, &wi) in w.iter().enumerate() {
            t -= wi;
            if t <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices from [0, n), order unspecified.
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(17);
        let picks = r.choose(20, 8);
        assert_eq!(picks.len(), 8);
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(19);
        let w = [0.05, 0.9, 0.05];
        let mut c = [0usize; 3];
        for _ in 0..5000 {
            c[r.weighted(&w)] += 1;
        }
        assert!(c[1] > 4000, "{c:?}");
    }
}
