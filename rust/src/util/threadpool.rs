//! Thread-pool / parallel-for substrate (tokio & rayon unavailable offline).
//!
//! A small fixed worker pool with a work queue (for long-lived
//! fire-and-forget jobs; currently exercised only by its tests), plus
//! three scoped data-parallel primitives:
//!
//! * [`parallel_for_chunks`] — read-only range fan-out (general
//!   primitive; the tensor GEMM does its own `split_at_mut` row split
//!   because each chunk needs exclusive output slices);
//! * [`parallel_for_slices_mut`] — disjoint `&mut` chunk fan-out
//!   (matvec output) with safety coming from `chunks_mut` rather than
//!   raw-pointer arithmetic;
//! * [`parallel_tasks`] — N independent borrowing jobs with results in
//!   index order (per-module pruning-database builds).
//!
//! All three are nesting-aware via [`thread_budget`]: a
//! `parallel_tasks` fan-out divides the hardware parallelism among
//! its workers, so inner kernels thread across the leftover share
//! when tasks are few and run inline when the fan-out already
//! saturates the machine. On a single-core testbed everything
//! degenerates to sequential execution.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

thread_local! {
    /// Per-thread parallelism budget set by enclosing parallel
    /// regions. 0 = unset (top level): the full hardware parallelism
    /// is available. [`parallel_tasks`] divides its budget among its
    /// workers, so an undersubscribed fan-out (4 modules on 16 cores)
    /// leaves each task a share of cores for its inner GEMM/matvec,
    /// while a saturated fan-out drives inner kernels inline instead
    /// of oversubscribing the machine with P×P threads.
    static PAR_BUDGET: Cell<usize> = const { Cell::new(0) };
}

/// How many threads the current thread may fan out across: the
/// hardware parallelism at top level, or the share left over by the
/// enclosing parallel region (≥1; 1 means "run inline").
pub fn thread_budget() -> usize {
    let b = PAR_BUDGET.with(|c| c.get());
    if b == 0 {
        thread::available_parallelism().map(|x| x.get()).unwrap_or(1)
    } else {
        b
    }
}

/// Whether the current thread is already inside a parallel region.
pub fn in_parallel_region() -> bool {
    PAR_BUDGET.with(|c| c.get()) != 0
}

/// Mark the current thread as a leaf worker: no parallelism budget
/// left, so any nested budget-gated kernel runs inline. Call only on
/// dedicated worker threads (the flag lives until the thread dies).
pub fn enter_leaf_region() {
    PAR_BUDGET.with(|c| c.set(1));
}

/// Run `f` with this thread's parallelism budget pinned to `budget`
/// (clamped to ≥ 1), restoring the previous budget afterwards (also
/// on panic). Test support for the thread-determinism contract: the
/// threaded kernels must produce bit-identical results across budgets
/// {1, 2, max} — this is how a test forces each one deterministically
/// regardless of the machine's core count.
pub fn with_thread_budget<T>(budget: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            PAR_BUDGET.with(|c| c.set(self.0));
        }
    }
    let prev = PAR_BUDGET.with(|c| c.get());
    let _guard = Restore(prev);
    PAR_BUDGET.with(|c| c.set(budget.max(1)));
    f()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("ziplm-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, size }
    }

    /// One pool per process is plenty here.
    pub fn global() -> &'static ThreadPool {
        use std::sync::OnceLock;
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            ThreadPool::new(n)
        })
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped data-parallel loop: splits [0, n) into chunks and runs `f(range)`
/// on scoped threads. Falls back to inline execution for small n or a
/// single hardware thread.
pub fn parallel_for_chunks<F>(n: usize, min_chunk: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = thread_budget();
    if threads <= 1 || n <= min_chunk {
        f(0..n);
        return;
    }
    let chunks = threads.min(n.div_ceil(min_chunk)).max(1);
    let per = n.div_ceil(chunks);
    let next = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..chunks {
            s.spawn(|| {
                enter_leaf_region();
                loop {
                    let start = next.fetch_add(per, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    f(start..(start + per).min(n));
                }
            });
        }
    });
}

/// Scoped data-parallel loop over disjoint `&mut` chunks of a slice:
/// `f(start, chunk)` gets the chunk's offset into `data` plus exclusive
/// access to it. This is the safe replacement for the old "disjoint
/// ranges write through a shared raw pointer" pattern — disjointness is
/// now proven by `chunks_mut`, not asserted in a comment.
pub fn parallel_for_slices_mut<T, F>(data: &mut [T], min_chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let threads = thread_budget();
    if threads <= 1 || n <= min_chunk {
        f(0, data);
        return;
    }
    let nchunks = threads.min(n.div_ceil(min_chunk)).max(1);
    let per = n.div_ceil(nchunks);
    // LIFO work bag of (offset, chunk) pairs; each worker pops until empty.
    let bag: Mutex<Vec<(usize, &mut [T])>> =
        Mutex::new(data.chunks_mut(per).enumerate().map(|(ci, c)| (ci * per, c)).collect());
    thread::scope(|s| {
        for _ in 0..nchunks {
            s.spawn(|| {
                enter_leaf_region();
                loop {
                    let item = bag.lock().unwrap().pop();
                    match item {
                        Some((start, chunk)) => f(start, chunk),
                        None => break,
                    }
                }
            });
        }
    });
}

/// Run `n` independent tasks `f(0..n)` concurrently and return their
/// results in index order. Concurrency is capped at the calling
/// thread's [`thread_budget`] (the hardware parallelism at top
/// level); the tasks run on scoped threads — not a queue whose jobs
/// must be `'static` — so they may borrow from the caller, which is
/// what the per-module database builds need: each task borrows the
/// PJRT engine and calibration Hessians while owning its backend.
/// The budget is divided among workers: with fewer tasks than cores
/// each task keeps a share for its inner threaded kernels
/// (GEMM/matvec), and with many tasks the inner kernels run inline
/// instead of oversubscribing the machine. Panics in a task
/// propagate after the scope joins.
pub fn parallel_tasks<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let budget = thread_budget();
    let workers = budget.min(n);
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let child_budget = (budget / workers).max(1);
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                PAR_BUDGET.with(|c| c.set(child_budget));
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    *slots[i].lock().unwrap() = Some(f(i));
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("parallel_tasks: missing result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_for_covers_everything_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(n, 64, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_empty_ok() {
        parallel_for_chunks(0, 8, |_| panic!("should not run"));
    }

    #[test]
    fn slices_mut_writes_every_element_once() {
        let n = 5_000;
        let mut data = vec![0u64; n];
        parallel_for_slices_mut(&mut data, 64, |start, chunk| {
            for (off, v) in chunk.iter_mut().enumerate() {
                *v += (start + off) as u64 + 1;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64 + 1);
        }
    }

    #[test]
    fn slices_mut_small_runs_inline() {
        let mut data = vec![1u8; 3];
        parallel_for_slices_mut(&mut data, 64, |start, chunk| {
            assert_eq!(start, 0);
            assert_eq!(chunk.len(), 3);
            chunk.fill(9);
        });
        assert_eq!(data, vec![9, 9, 9]);
    }

    #[test]
    fn tasks_return_in_index_order() {
        let inputs: Vec<usize> = (0..97).collect();
        let out = parallel_tasks(inputs.len(), |i| inputs[i] * 3);
        assert_eq!(out, (0..97).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn tasks_empty_ok() {
        let out: Vec<u32> = parallel_tasks(0, |_| unreachable!());
        assert!(out.is_empty());
    }

    #[test]
    fn nested_parallel_runs_inline_and_stays_correct() {
        // inner parallel_for_chunks inside a parallel_tasks worker must
        // degrade to inline execution (no nested spawning) yet still
        // cover every index exactly once.
        let outer = 6;
        let out = parallel_tasks(outer, |t| {
            let hw = thread::available_parallelism().map(|x| x.get()).unwrap_or(1);
            assert!(hw <= 1 || in_parallel_region());
            let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
            parallel_for_chunks(1000, 8, |range| {
                for i in range {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            let total: u64 = hits.iter().map(|h| h.load(Ordering::Relaxed)).sum();
            (t, total)
        });
        for (idx, (t, total)) in out.iter().enumerate() {
            assert_eq!(*t, idx);
            assert_eq!(*total, 1000);
        }
    }
}
