//! Thread-pool / parallel-for substrate (tokio & rayon unavailable offline).
//!
//! A small fixed worker pool with a work queue, plus a scoped
//! `parallel_for` used by the tensor GEMM and the SPDY search. On this
//! single-core testbed the pool mostly degenerates to sequential
//! execution, but the coordinator (request batcher) still relies on it
//! for concurrency (I/O-style waiting), and on multi-core hosts the
//! GEMM scales.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("ziplm-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, size }
    }

    /// One pool per process is plenty here.
    pub fn global() -> &'static ThreadPool {
        use std::sync::OnceLock;
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            ThreadPool::new(n)
        })
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped data-parallel loop: splits [0, n) into chunks and runs `f(range)`
/// on scoped threads. Falls back to inline execution for small n or a
/// single hardware thread.
pub fn parallel_for_chunks<F>(n: usize, min_chunk: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = thread::available_parallelism().map(|x| x.get()).unwrap_or(1);
    if threads <= 1 || n <= min_chunk {
        f(0..n);
        return;
    }
    let chunks = threads.min(n.div_ceil(min_chunk)).max(1);
    let per = n.div_ceil(chunks);
    let next = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..chunks {
            s.spawn(|| loop {
                let start = next.fetch_add(per, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                f(start..(start + per).min(n));
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_for_covers_everything_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(n, 64, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_empty_ok() {
        parallel_for_chunks(0, 8, |_| panic!("should not run"));
    }
}
