//! Tiny CLI argument parser substrate (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and free
//! positional arguments. Each subcommand of the `ziplm` launcher builds
//! one of these from `std::env::args`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag
                    match iter.peek() {
                        Some(nxt) if !nxt.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list of f64 (e.g. `--speedups 2,3,4`).
    pub fn f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        match self.get(key) {
            Some(s) => s.split(',').filter_map(|x| x.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_styles() {
        let a = parse("run --model bert --epochs=3 --verbose --out dir");
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("model"), Some("bert"));
        assert_eq!(a.usize_or("epochs", 0), 3);
        assert!(a.bool("verbose"));
        assert_eq!(a.get("out"), Some("dir"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.f64_or("missing", 1.5), 1.5);
        assert!(!a.bool("missing"));
    }

    #[test]
    fn f64_list() {
        let a = parse("--speedups 2,3.5,10");
        assert_eq!(a.f64_list("speedups", &[]), vec![2.0, 3.5, 10.0]);
        assert_eq!(a.f64_list("other", &[1.0]), vec![1.0]);
    }

    #[test]
    fn trailing_flag_is_bool() {
        let a = parse("--a 1 --b");
        assert_eq!(a.get("a"), Some("1"));
        assert!(a.bool("b"));
    }
}
