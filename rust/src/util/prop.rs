//! Property-testing substrate (proptest is unavailable offline).
//!
//! Seeded random-input property runner with failure reporting and
//! simple halving shrink for numeric vectors. Coordinator invariants
//! (routing, batching, OBS algebra, SPDY feasibility) are tested with
//! this in rust/tests/proptests.rs and module unit tests.

use super::rng::Rng;

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

const DEFAULT_SEED: u64 = 0x5a1b_c0de;

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 64, seed: DEFAULT_SEED }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Self {
        Prop { cases, seed: DEFAULT_SEED }
    }

    /// Run `prop` on `cases` random inputs produced by `gen`.
    /// Panics with the failing seed + debug repr on first failure.
    pub fn check<T: std::fmt::Debug, G, P>(&self, name: &str, mut gen: G, mut prop: P)
    where
        G: FnMut(&mut Rng) -> T,
        P: FnMut(&T) -> bool,
    {
        for case in 0..self.cases {
            let mut rng = Rng::new(self.seed.wrapping_add(case as u64));
            let input = gen(&mut rng);
            if !prop(&input) {
                panic!(
                    "property `{name}` failed on case {case} (seed {}):\n{input:#?}",
                    self.seed.wrapping_add(case as u64)
                );
            }
        }
    }

    /// check() with an explicit error message from the property.
    pub fn check_msg<T: std::fmt::Debug, G, P>(&self, name: &str, mut gen: G, mut prop: P)
    where
        G: FnMut(&mut Rng) -> T,
        P: FnMut(&T) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let mut rng = Rng::new(self.seed.wrapping_add(case as u64));
            let input = gen(&mut rng);
            if let Err(msg) = prop(&input) {
                panic!(
                    "property `{name}` failed on case {case} (seed {}): {msg}\n{input:#?}",
                    self.seed.wrapping_add(case as u64)
                );
            }
        }
    }
}

/// Generators for common shapes.
pub mod gen {
    use super::super::rng::Rng;

    pub fn vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32(scale)).collect()
    }

    /// Random SPD matrix (row-major n x n) = A A^T + n*I*damp.
    pub fn spd(rng: &mut Rng, n: usize, damp: f32) -> Vec<f32> {
        let a = vec_f32(rng, n * n, 1.0);
        let mut h = vec![0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * a[j * n + k];
                }
                h[i * n + j] = s;
            }
        }
        for i in 0..n {
            h[i * n + i] += damp * n as f32;
        }
        h
    }

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Prop::new(32).check("abs-nonneg", |r| r.normal_f32(2.0), |x| x.abs() >= 0.0);
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn reports_failure() {
        Prop::new(4).check("always-false", |r| r.below(10), |_| false);
    }

    #[test]
    fn spd_is_symmetric_posdiag() {
        let mut r = Rng::new(3);
        let n = 8;
        let h = gen::spd(&mut r, n, 0.1);
        for i in 0..n {
            assert!(h[i * n + i] > 0.0);
            for j in 0..n {
                assert!((h[i * n + j] - h[j * n + i]).abs() < 1e-4);
            }
        }
    }
}
