//! # ZipLM — Inference-Aware Structured Pruning of Language Models
//!
//! A from-scratch reproduction of *ZipLM* (Kurtic, Frantar, Alistarh;
//! NeurIPS 2023) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: gradual/one-shot pruning
//!   drivers, structured SPDY search, latency tables, fine-tuning loop,
//!   baselines, evaluation, and an inference server used for runtime
//!   measurements. Owns the event loop, CLI and metrics.
//! * **L2 (python/compile, build-time only)** — masked transformer
//!   fwd/train graphs + pruning score/update graphs, AOT-lowered to HLO
//!   text once (`make artifacts`).
//! * **L1 (python/compile/kernels)** — Pallas kernels for the pruning
//!   hot-spots (structured-OBS scoring, rank-g updates) and the fused
//!   head-masked attention core.
//!
//! The request path is pure Rust → PJRT; Python never executes after
//! artifacts are built. See DESIGN.md for the full system inventory;
//! the experiment drivers (`exp/`) write paper-vs-measured results
//! under `results/`.

// `clippy.toml` bans `unwrap`/`expect` workspace-wide so the serving
// core (`coordinator`, `runtime`, `session`) can never grow a panic
// path unnoticed (DESIGN.md §10). Modules outside that core opt out
// here; their test mods and the test/bench/example crates opt out at
// their own roots.
pub mod adapt;
#[allow(clippy::disallowed_methods)]
pub mod baselines;
#[allow(clippy::disallowed_methods)]
pub mod compress;
pub mod coordinator;
#[allow(clippy::disallowed_methods)]
pub mod data;
#[allow(clippy::disallowed_methods)]
pub mod env;
#[allow(clippy::disallowed_methods)]
pub mod eval;
#[allow(clippy::disallowed_methods)]
pub mod exp;
pub mod kernel;
#[allow(clippy::disallowed_methods)]
pub mod latency;
#[allow(clippy::disallowed_methods)]
pub mod models;
#[allow(clippy::disallowed_methods)]
pub mod pruner;
#[allow(clippy::disallowed_methods)]
pub mod quant;
pub mod runtime;
pub mod session;
#[allow(clippy::disallowed_methods)]
pub mod spdy;
#[allow(clippy::disallowed_methods)]
pub mod tensor;
#[allow(clippy::disallowed_methods)]
pub mod train;
#[allow(clippy::disallowed_methods)]
pub mod util;
#[allow(clippy::disallowed_methods)]
pub mod ziplm;
