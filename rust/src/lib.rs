//! # ZipLM — Inference-Aware Structured Pruning of Language Models
//!
//! A from-scratch reproduction of *ZipLM* (Kurtic, Frantar, Alistarh;
//! NeurIPS 2023) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: gradual/one-shot pruning
//!   drivers, structured SPDY search, latency tables, fine-tuning loop,
//!   baselines, evaluation, and an inference server used for runtime
//!   measurements. Owns the event loop, CLI and metrics.
//! * **L2 (python/compile, build-time only)** — masked transformer
//!   fwd/train graphs + pruning score/update graphs, AOT-lowered to HLO
//!   text once (`make artifacts`).
//! * **L1 (python/compile/kernels)** — Pallas kernels for the pruning
//!   hot-spots (structured-OBS scoring, rank-g updates) and the fused
//!   head-masked attention core.
//!
//! The request path is pure Rust → PJRT; Python never executes after
//! artifacts are built. See DESIGN.md for the full system inventory;
//! the experiment drivers (`exp/`) write paper-vs-measured results
//! under `results/`.

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod env;
pub mod eval;
pub mod exp;
pub mod latency;
pub mod models;
pub mod pruner;
pub mod quant;
pub mod runtime;
pub mod session;
pub mod spdy;
pub mod tensor;
pub mod train;
pub mod util;
pub mod ziplm;
