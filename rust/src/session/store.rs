//! Stage checkpoint store: the resume-after-crash substrate of a
//! [`super::CompressionSession`].
//!
//! Every pipeline stage funnels through [`StageStore::load_or_compute`]:
//! with a checkpoint directory attached, a completed stage's artifact is
//! written to `<dir>/<key>` and a re-opened session loads it instead of
//! recomputing; without a directory the store degenerates to "always
//! compute". The `computed`/`loaded` counters make resume behavior
//! directly assertable (no timing involved).
//!
//! Checkpoints are only as trustworthy as their inputs, so every blob
//! header records a [`fingerprint`] of the model state it was derived
//! from (the session folds its config into it via
//! [`fingerprint_with`]); a loader that finds a mismatching
//! fingerprint reports a miss and the stage recomputes. Binary blobs use the same shape as the
//! `.zlm` checkpoints (magic, JSON header, raw f32 LE payload).

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{anyhow, Context, Result};

use crate::env::InferenceEnv;
use crate::models::ModelState;
use crate::pruner::Hessians;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::ziplm::{LevelSnapshot, ModuleDb};

/// FNV-1a over a byte stream; cheap, stable across runs, good enough
/// to catch "resumed with a different model state" mistakes.
fn fnv1a(bytes: impl Iterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint of a model state (params + masks), hex-encoded for JSON
/// headers (f64 cannot hold a u64 exactly).
pub fn fingerprint(state: &ModelState) -> String {
    fingerprint_with(state, &[])
}

/// [`fingerprint`] with extra context bytes folded in — the session
/// passes an encoding of its prune/train configuration and teacher so
/// checkpoints produced under different knobs never collide.
pub fn fingerprint_with(state: &ModelState, context: &[u8]) -> String {
    let params = state.params.iter().flat_map(|x| x.to_le_bytes());
    let head = state.masks.head.iter().flat_map(|x| x.to_le_bytes());
    let ffn = state.masks.ffn.iter().flat_map(|x| x.to_le_bytes());
    let ctxt = context.iter().copied();
    format!("{:016x}", fnv1a(params.chain(head).chain(ffn).chain(ctxt)))
}

/// Fingerprint of an inference environment's serialized JSON form.
/// This is the env half of the multi-env checkpoint scheme: capture
/// artifacts (Hessians, databases) are keyed env-free, while every
/// solve-side artifact folds this value into both its file name and
/// its stored fingerprint, so N environments' certifications coexist
/// in one session directory without ever cross-loading.
pub fn env_fingerprint(env: &InferenceEnv) -> String {
    format!("{:016x}", fnv1a(env.to_json().to_string().bytes()))
}

/// Load-or-compute gate over one checkpoint directory.
pub struct StageStore {
    dir: Option<PathBuf>,
    computed: AtomicUsize,
    loaded: AtomicUsize,
}

impl StageStore {
    /// A store writing under `dir`, or an always-compute store when
    /// `dir` is `None`.
    pub fn new(dir: Option<PathBuf>) -> StageStore {
        StageStore { dir, computed: AtomicUsize::new(0), loaded: AtomicUsize::new(0) }
    }

    /// Checkpoint directory, if any.
    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// `(computed, loaded)` artifact counts so far (one per
    /// [`StageStore::load_or_compute`] call). A resumed session that
    /// found every checkpoint reports `computed == 0`.
    pub fn counters(&self) -> (usize, usize) {
        (self.computed.load(Ordering::Relaxed), self.loaded.load(Ordering::Relaxed))
    }

    /// Fetch the artifact for `key`: load it from the checkpoint file
    /// when present and valid (a `load` returning `None` — missing,
    /// corrupt, or fingerprint-mismatched — falls through), otherwise
    /// compute and persist it. Returns the artifact plus whether it
    /// was loaded from disk.
    pub fn load_or_compute<T>(
        &self,
        key: &str,
        load: impl FnOnce(&Path) -> Option<T>,
        save: impl FnOnce(&Path, &T) -> Result<()>,
        compute: impl FnOnce() -> Result<T>,
    ) -> Result<(T, bool)> {
        if let Some(dir) = &self.dir {
            let path = dir.join(key);
            if path.exists() {
                if let Some(v) = load(&path) {
                    self.loaded.fetch_add(1, Ordering::Relaxed);
                    return Ok((v, true));
                }
                // a present-but-unloadable checkpoint (truncated blob,
                // bad magic, fingerprint mismatch) is a cache miss,
                // never an abort — but losing a resume silently would
                // hide real corruption, so say why we recompute
                eprintln!(
                    "[store] checkpoint `{key}` exists but failed to load \
                     (corrupt or stale); recomputing"
                );
            }
            let v = compute()?;
            std::fs::create_dir_all(dir)?;
            save(&path, &v).with_context(|| format!("checkpointing stage `{key}`"))?;
            self.computed.fetch_add(1, Ordering::Relaxed);
            Ok((v, false))
        } else {
            let v = compute()?;
            self.computed.fetch_add(1, Ordering::Relaxed);
            Ok((v, false))
        }
    }
}

// ----------------------------------------------------------- blob I/O

const MAGIC: &[u8; 4] = b"ZLS1";

/// Write a stage blob: magic, JSON header, raw f32 LE payload.
pub fn write_blob(path: &Path, header: &Json, payload: &[f32]) -> Result<()> {
    let text = header.to_string();
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(text.len() as u64).to_le_bytes())?;
    f.write_all(text.as_bytes())?;
    let mut buf = Vec::with_capacity(payload.len() * 4);
    for &x in payload {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Read a stage blob written by [`write_blob`].
pub fn read_blob(path: &Path) -> Result<(Json, Vec<f32>)> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(anyhow!("bad stage-blob magic"));
    }
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let hlen = u64::from_le_bytes(len8);
    // the header length is untrusted input: a truncated or scribbled
    // blob can declare terabytes here, and `vec![0u8; hlen]` would
    // abort the process before read_exact ever fails. Bound it by what
    // the file can actually hold past the 12-byte preamble.
    let file_len = f.metadata()?.len();
    let avail = file_len.saturating_sub(MAGIC.len() as u64 + 8);
    if hlen > avail {
        return Err(anyhow!(
            "stage blob header claims {hlen} bytes but only {avail} remain (truncated?)"
        ));
    }
    let mut hbuf = vec![0u8; hlen as usize];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?).map_err(|e| anyhow!(e))?;
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    if raw.len() % 4 != 0 {
        return Err(anyhow!("stage blob truncated"));
    }
    let payload =
        raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
    Ok((header, payload))
}

// ----------------------------------------------- per-artifact codecs

/// Persist captured Hessians, stamped with the source-state fingerprint.
pub fn save_hessians(path: &Path, fp: &str, hs: &Hessians) -> Result<()> {
    let dims = |ts: &[Tensor]| Json::arr_usize(&ts.iter().map(|t| t.rows()).collect::<Vec<_>>());
    let header = Json::obj(vec![
        ("kind", Json::Str("hessians".into())),
        ("fingerprint", Json::Str(fp.to_string())),
        ("attn", dims(&hs.attn)),
        ("ffn", dims(&hs.ffn)),
    ]);
    let mut payload = Vec::new();
    for t in hs.attn.iter().chain(&hs.ffn) {
        payload.extend_from_slice(&t.data);
    }
    write_blob(path, &header, &payload)
}

/// Load Hessians if the blob is intact and matches `fp`.
pub fn load_hessians(path: &Path, fp: &str) -> Option<Hessians> {
    let (header, payload) = read_blob(path).ok()?;
    if header.get("kind")?.as_str()? != "hessians" || header.get("fingerprint")?.as_str()? != fp {
        return None;
    }
    let attn_dims = header.get("attn")?.usize_array();
    let ffn_dims = header.get("ffn")?.usize_array();
    let total: usize = attn_dims.iter().map(|&d| d * d).sum::<usize>()
        + ffn_dims.iter().map(|&d| d * d).sum::<usize>();
    if payload.len() != total {
        return None;
    }
    let mut off = 0usize;
    let mut take = |d: usize| {
        let t = Tensor::from_vec(&[d, d], payload[off..off + d * d].to_vec());
        off += d * d;
        t
    };
    let attn: Vec<Tensor> = attn_dims.iter().map(|&d| take(d)).collect();
    let ffn: Vec<Tensor> = ffn_dims.iter().map(|&d| take(d)).collect();
    Some(Hessians { attn, ffn })
}

/// Persist the per-module databases (level snapshots + priors).
pub fn save_dbs(path: &Path, fp: &str, dbs: &[ModuleDb]) -> Result<()> {
    let mut payload = Vec::new();
    let modules: Vec<Json> = dbs
        .iter()
        .map(|db| {
            let levels: Vec<Json> = db
                .levels
                .iter()
                .map(|lvl| {
                    payload.extend_from_slice(&lvl.w.data);
                    Json::obj(vec![
                        ("remaining", Json::Num(lvl.remaining as f64)),
                        ("dead", Json::arr_usize(&lvl.dead)),
                        ("prior", Json::Num(lvl.prior)),
                        ("rows", Json::Num(lvl.w.rows() as f64)),
                        ("cols", Json::Num(lvl.w.cols() as f64)),
                    ])
                })
                .collect();
            Json::obj(vec![
                ("layer", Json::Num(db.layer as f64)),
                ("is_attn", Json::Bool(db.is_attn)),
                ("levels", Json::Arr(levels)),
            ])
        })
        .collect();
    let header = Json::obj(vec![
        ("kind", Json::Str("dbs".into())),
        ("fingerprint", Json::Str(fp.to_string())),
        ("modules", Json::Arr(modules)),
    ]);
    write_blob(path, &header, &payload)
}

/// Load databases if the blob is intact and matches `fp`.
pub fn load_dbs(path: &Path, fp: &str) -> Option<Vec<ModuleDb>> {
    let (header, payload) = read_blob(path).ok()?;
    if header.get("kind")?.as_str()? != "dbs" || header.get("fingerprint")?.as_str()? != fp {
        return None;
    }
    let mut off = 0usize;
    let mut out = Vec::new();
    for m in header.get("modules")?.as_arr()? {
        let mut levels = Vec::new();
        for lvl in m.get("levels")?.as_arr()? {
            let rows = lvl.get("rows")?.as_usize()?;
            let cols = lvl.get("cols")?.as_usize()?;
            if off + rows * cols > payload.len() {
                return None;
            }
            let w = Tensor::from_vec(&[rows, cols], payload[off..off + rows * cols].to_vec());
            off += rows * cols;
            levels.push(LevelSnapshot {
                remaining: lvl.get("remaining")?.as_usize()?,
                dead: lvl.get("dead")?.usize_array(),
                w,
                prior: lvl.get("prior")?.as_f64()?,
            });
        }
        out.push(ModuleDb {
            layer: m.get("layer")?.as_usize()?,
            is_attn: m.get("is_attn")?.as_bool()?,
            levels,
        });
    }
    if off != payload.len() {
        return None;
    }
    Some(out)
}

/// Persist a solved profile (level indices + search loss) for a target.
pub fn save_profile(
    path: &Path,
    fp: &str,
    target: f64,
    profile: &[usize],
    best_loss: f64,
) -> Result<()> {
    let j = Json::obj(vec![
        ("kind", Json::Str("profile".into())),
        ("fingerprint", Json::Str(fp.to_string())),
        ("target", Json::Num(target)),
        ("profile", Json::arr_usize(profile)),
        // non-finite losses have no JSON literal; Null round-trips them
        ("best_loss", if best_loss.is_finite() { Json::Num(best_loss) } else { Json::Null }),
    ]);
    if let Some(d) = path.parent() {
        std::fs::create_dir_all(d)?;
    }
    std::fs::write(path, j.to_pretty())?;
    Ok(())
}

/// Load a solved profile if it matches `fp` and `target`.
pub fn load_profile(path: &Path, fp: &str, target: f64) -> Option<(Vec<usize>, f64)> {
    let text = std::fs::read_to_string(path).ok()?;
    let j = Json::parse(&text).ok()?;
    if j.get("kind")?.as_str()? != "profile"
        || j.get("fingerprint")?.as_str()? != fp
        || j.get("target")?.as_f64()? != target
    {
        return None;
    }
    let best_loss = j.get("best_loss").and_then(Json::as_f64).unwrap_or(f64::INFINITY);
    Some((j.get("profile")?.usize_array(), best_loss))
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ziplm_store_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// Trivial JSON codec for a Vec<f64> test payload. (`&Vec` rather
    /// than `&[_]` because the signature must match the store's
    /// `FnOnce(&Path, &T)` with `T = Vec<f64>`.)
    #[allow(clippy::ptr_arg)]
    fn save_vec(path: &Path, v: &Vec<f64>) -> Result<()> {
        std::fs::write(path, Json::arr_f64(v).to_string())?;
        Ok(())
    }

    fn load_vec(path: &Path) -> Option<Vec<f64>> {
        let j = Json::parse(&std::fs::read_to_string(path).ok()?).ok()?;
        Some(j.as_arr()?.iter().filter_map(Json::as_f64).collect())
    }

    /// Satellite acceptance: a re-opened store over the same directory
    /// loads every checkpointed stage instead of recomputing — asserted
    /// purely through counters, no timing.
    #[test]
    fn reopened_store_loads_instead_of_recomputing() {
        let dir = temp_dir("resume");
        let runs = AtomicUsize::new(0);
        let stage_keys = ["s0_a.json", "s0_b.json", "s1_a.json"];

        let first = StageStore::new(Some(dir.clone()));
        for (i, key) in stage_keys.iter().enumerate() {
            let (v, loaded) = first
                .load_or_compute(
                    key,
                    load_vec,
                    save_vec,
                    || {
                        runs.fetch_add(1, Ordering::SeqCst);
                        Ok(vec![i as f64, 2.0 * i as f64])
                    },
                )
                .unwrap();
            assert!(!loaded);
            assert_eq!(v, vec![i as f64, 2.0 * i as f64]);
        }
        assert_eq!(first.counters(), (3, 0));
        assert_eq!(runs.load(Ordering::SeqCst), 3);

        // resume: same dir, new store — every stage must load
        let second = StageStore::new(Some(dir.clone()));
        for (i, key) in stage_keys.iter().enumerate() {
            let (v, loaded) = second
                .load_or_compute(key, load_vec, save_vec, || {
                    panic!("stage `{key}` recomputed on resume")
                })
                .unwrap();
            assert!(loaded);
            assert_eq!(v, vec![i as f64, 2.0 * i as f64]);
        }
        assert_eq!(second.counters(), (0, 3));
        assert_eq!(runs.load(Ordering::SeqCst), 3, "compute ran again on resume");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn no_dir_store_always_computes_and_persists_nothing() {
        let store = StageStore::new(None);
        for _ in 0..2 {
            let (v, loaded) =
                store.load_or_compute("k.json", load_vec, save_vec, || Ok(vec![1.0])).unwrap();
            assert!(!loaded);
            assert_eq!(v, vec![1.0]);
        }
        assert_eq!(store.counters(), (2, 0));
    }

    #[test]
    fn corrupt_checkpoint_falls_back_to_compute() {
        let dir = temp_dir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("bad.json"), b"{ not json").unwrap();
        let store = StageStore::new(Some(dir.clone()));
        let (v, loaded) =
            store.load_or_compute("bad.json", load_vec, save_vec, || Ok(vec![7.0])).unwrap();
        assert!(!loaded);
        assert_eq!(v, vec![7.0]);
        assert_eq!(store.counters(), (1, 0));
        // the recompute overwrote the corrupt file: next open loads
        let again = StageStore::new(Some(dir.clone()));
        let (_, loaded) = again
            .load_or_compute("bad.json", load_vec, save_vec, || panic!("recomputed"))
            .unwrap();
        assert!(loaded);
        let _ = std::fs::remove_dir_all(dir);
    }

    /// Satellite regression: a truncated or corrupted ZLS1 blob must
    /// read as an error (→ cache miss upstream), never panic or abort.
    #[test]
    fn truncated_or_corrupt_blob_is_a_miss_not_a_panic() {
        let dir = temp_dir("trunc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.bin");
        let header = Json::obj(vec![("kind", Json::Str("hessians".into()))]);
        write_blob(&path, &header, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let whole = std::fs::read(&path).unwrap();

        // every proper prefix must fail cleanly (mid-magic, mid-length,
        // mid-header, mid-payload) — sweep them all. A cut inside the
        // payload at a 4-byte boundary parses as a SHORTER payload by
        // design; the typed loaders catch that via their size checks.
        let payload_start = whole.len() - 16; // 4 f32s
        for cut in 0..whole.len() {
            std::fs::write(&path, &whole[..cut]).unwrap();
            match read_blob(&path) {
                Err(_) => {}
                Ok((_, p)) => {
                    assert!(
                        cut >= payload_start && (cut - payload_start) % 4 == 0,
                        "prefix of {cut} bytes parsed but should not have"
                    );
                    assert!(p.len() < 4, "short read returned a whole payload");
                }
            }
            // and through the typed loader: miss, not panic
            assert!(load_hessians(&path, "fp").is_none());
        }

        // a scribbled header length claiming more than the file holds
        // must error out instead of attempting a giant allocation
        let mut huge = whole.clone();
        huge[4..12].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &huge).unwrap();
        let err = read_blob(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "unexpected error: {err}");

        // bad magic
        let mut bad = whole.clone();
        bad[0] = b'X';
        std::fs::write(&path, &bad).unwrap();
        assert!(read_blob(&path).is_err());

        // intact blob still reads after all that
        std::fs::write(&path, &whole).unwrap();
        let (h, p) = read_blob(&path).unwrap();
        assert_eq!(h.get("kind").and_then(Json::as_str), Some("hessians"));
        assert_eq!(p, vec![1.0, 2.0, 3.0, 4.0]);
        let _ = std::fs::remove_dir_all(dir);
    }

    /// A truncated blob behind the store is a recompute, not a crash.
    #[test]
    fn truncated_blob_checkpoint_recomputes() {
        let dir = temp_dir("trunc_store");
        std::fs::create_dir_all(&dir).unwrap();
        let hs = Hessians {
            attn: vec![Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0])],
            ffn: vec![Tensor::from_vec(&[2, 2], vec![5.0, 6.0, 7.0, 8.0])],
        };
        let key = "hess.bin";
        save_hessians(&dir.join(key), "fp", &hs).unwrap();
        // truncate the checkpoint mid-payload
        let whole = std::fs::read(dir.join(key)).unwrap();
        std::fs::write(dir.join(key), &whole[..whole.len() - 6]).unwrap();
        let store = StageStore::new(Some(dir.clone()));
        let (back, loaded) = store
            .load_or_compute(
                key,
                |p| load_hessians(p, "fp"),
                |p, v| save_hessians(p, "fp", v),
                || Ok(hs.clone()),
            )
            .unwrap();
        assert!(!loaded, "truncated blob must be a miss");
        assert_eq!(back.attn[0].data, hs.attn[0].data);
        assert_eq!(store.counters(), (1, 0));
        // the recompute rewrote it whole: a fresh store now loads
        let again = StageStore::new(Some(dir.clone()));
        let (_, loaded) = again
            .load_or_compute(
                key,
                |p| load_hessians(p, "fp"),
                |p, v| save_hessians(p, "fp", v),
                || panic!("recomputed after repair"),
            )
            .unwrap();
        assert!(loaded);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn hessian_blob_roundtrip_and_fingerprint_gate() {
        let dir = temp_dir("hess");
        std::fs::create_dir_all(&dir).unwrap();
        let hs = Hessians {
            attn: vec![Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0])],
            ffn: vec![Tensor::from_vec(&[3, 3], (0..9).map(|x| x as f32).collect())],
        };
        let path = dir.join("h.bin");
        save_hessians(&path, "aabb", &hs).unwrap();
        let back = load_hessians(&path, "aabb").expect("roundtrip");
        assert_eq!(back.attn[0].data, hs.attn[0].data);
        assert_eq!(back.ffn[0].data, hs.ffn[0].data);
        // a different source state must not reuse the blob
        assert!(load_hessians(&path, "ccdd").is_none());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn dbs_blob_roundtrip() {
        let dir = temp_dir("dbs");
        std::fs::create_dir_all(&dir).unwrap();
        let dbs = vec![ModuleDb {
            layer: 1,
            is_attn: true,
            levels: vec![
                LevelSnapshot {
                    remaining: 2,
                    dead: vec![],
                    w: Tensor::from_vec(&[2, 4], (0..8).map(|x| x as f32).collect()),
                    prior: 0.0,
                },
                LevelSnapshot {
                    remaining: 1,
                    dead: vec![3],
                    w: Tensor::from_vec(&[2, 4], vec![0.5; 8]),
                    prior: 0.25,
                },
            ],
        }];
        let path = dir.join("d.bin");
        save_dbs(&path, "ff00", &dbs).unwrap();
        let back = load_dbs(&path, "ff00").expect("roundtrip");
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].layer, 1);
        assert!(back[0].is_attn);
        assert_eq!(back[0].levels[1].dead, vec![3]);
        assert_eq!(back[0].levels[1].prior, 0.25);
        assert_eq!(back[0].levels[0].w.data, dbs[0].levels[0].w.data);
        assert!(load_dbs(&path, "0001").is_none());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn profile_json_roundtrip_checks_target_and_fp() {
        let dir = temp_dir("prof");
        let path = dir.join("p.json");
        save_profile(&path, "ab", 2.0, &[0, 3, 1], 0.125).unwrap();
        assert_eq!(load_profile(&path, "ab", 2.0), Some((vec![0, 3, 1], 0.125)));
        assert!(load_profile(&path, "ab", 3.0).is_none());
        assert!(load_profile(&path, "xy", 2.0).is_none());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn env_fingerprint_stable_and_discriminating() {
        use crate::latency::LatencyTable;
        let table = |ov: f64| LatencyTable {
            model: "m".into(),
            device: "d".into(),
            regime: "throughput".into(),
            attn: vec![0.0, 1e-3],
            mlp: vec![(8, 4e-3), (0, 0.0)],
            overhead: ov,
        };
        let a = InferenceEnv::measured(table(1e-3)).unwrap();
        let b = InferenceEnv::measured(table(1e-3)).unwrap();
        let c = InferenceEnv::measured(table(2e-3)).unwrap();
        assert_eq!(env_fingerprint(&a), env_fingerprint(&b));
        assert_ne!(env_fingerprint(&a), env_fingerprint(&c));
        // the batch shape is part of the env's identity too
        assert_ne!(
            env_fingerprint(&a),
            env_fingerprint(&a.clone().with_batch_shape(8, 128))
        );
    }

    #[test]
    fn fingerprint_tracks_params_and_masks() {
        use crate::models::Masks;
        let st = |p: f32, m: f32| ModelState {
            model: "m".into(),
            task: "t".into(),
            params: vec![p; 4],
            masks: Masks { n_layers: 1, n_heads: 2, d_ff: 2, head: vec![m, 1.0], ffn: vec![1.0, 1.0] },
        };
        let a = fingerprint(&st(1.0, 1.0));
        assert_eq!(a, fingerprint(&st(1.0, 1.0)));
        assert_ne!(a, fingerprint(&st(2.0, 1.0)));
        assert_ne!(a, fingerprint(&st(1.0, 0.0)));
        // context bytes (session config) also discriminate
        let s = st(1.0, 1.0);
        assert_eq!(fingerprint_with(&s, b"cfgA"), fingerprint_with(&s, b"cfgA"));
        assert_ne!(fingerprint_with(&s, b"cfgA"), fingerprint_with(&s, b"cfgB"));
        assert_eq!(fingerprint(&s), fingerprint_with(&s, &[]));
    }
}
