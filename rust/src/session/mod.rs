//! CompressionSession: the typed end-to-end pipeline API.
//!
//! One session owns one compression run of one `(model, task)`
//! (DESIGN.md §7–§8). The flow the paper's Fig. 1 describes becomes a
//! chain of stage values, each owning its artifacts:
//!
//! ```text
//! CompressionSession::for_model(&engine, model, task)
//!     .with_env(env).with_targets(&[2.0, 4.0]) ... .open()?
//!   .capture(&state, &data)?        -> Captured   (Hessians)
//!   .build_dbs()?                   -> Databases  (per-module OBS ladders)
//!   .solve(&data, target)?          -> Solved     (SPDY profile)
//!   .apply()?                       -> Variant    (pruned ModelState + report)
//! session.run(teacher, &data)?      — gradual: the chain per target + fine-tune
//! session.emit_family(..)?          — manifest + member checkpoints
//! ```
//!
//! Environments are a first-class *axis* of a session, not part of its
//! identity (DESIGN.md §8): the Hessians and databases a capture
//! produces are env-independent artifacts, and only the SPDY solve
//! prices against an [`InferenceEnv`]. Two entry points exploit that:
//!
//! * [`CompressionSession::retarget`] swaps the session's env mid-run
//!   — the next solve re-prices the *same* checkpointed databases
//!   against the new cost model, with zero Hessian recomputation;
//! * [`CompressionSession::emit_families`] runs one capture + database
//!   build and then solves against N environments in parallel on the
//!   global pool, emitting one certified [`FamilyManifest`] per env
//!   (each embedding the env it was certified against — the exact
//!   value `serve-family` later admits requests with).
//!
//! With a checkpoint directory attached ([`SessionBuilder::checkpoint_to`])
//! every stage persists its artifact; re-opening a session over the
//! same directory resumes after a crash by loading completed stages
//! instead of recomputing them (each checkpoint is fingerprint-gated
//! to the model state it was derived from, so a divergent resume
//! recomputes rather than silently reusing stale artifacts). Capture
//! artifacts are keyed env-free; solve artifacts fold
//! [`store::env_fingerprint`] into both key and fingerprint
//! ([`solve_key`]/[`solve_fingerprint`]), so N envs' certifications
//! coexist in one directory without cross-loading. The
//! [`CompressionSession::counters`] pair `(computed, loaded)` and the
//! [`SessionBuilder::on_progress`] hook make both paths observable —
//! the CLI and experiment drivers render them.

pub mod pipeline;
pub mod registry;
pub mod store;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::compress::{ChoiceProblem, CompressionProfile};
use crate::data::Dataset;
use crate::env::InferenceEnv;
use crate::models::family::FamilyManifest;
use crate::models::ModelState;
use crate::pruner::{Hessians, PruneCfg, PruneReport, StageResult};
use crate::runtime::{Engine, ModelInfo, TaskInfo};
use crate::spdy::SpdyProblem;
use crate::train::{TrainCfg, Trainer};
use crate::util::json::Json;
use crate::util::threadpool::parallel_tasks;
use crate::ziplm::ModuleDb;

use store::StageStore;

/// Checkpoint key of the solved profile for gradual stage `idx` at
/// `target`, certified against the env with fingerprint `env_fp`. The
/// env fingerprint in the *name* is what lets a retargeted or
/// multi-env session keep every environment's certification side by
/// side; the target keeps distinct speedups from overwriting each
/// other inside one stage.
pub fn solve_key(idx: usize, env_fp: &str, target: f64) -> String {
    format!("s{idx}_profile_{env_fp}_t{target}.json")
}

/// Fingerprint stored inside solve-side artifacts: the capture-side
/// state/config fingerprint with the env fingerprint folded in. A
/// loader that finds a different env's fingerprint reports a miss and
/// the solve recomputes — the second gate behind [`solve_key`].
pub fn solve_fingerprint(stage_fp: &str, env_fp: &str) -> String {
    format!("{stage_fp}|env:{env_fp}")
}

/// Directory slug for one environment's family under
/// [`CompressionSession::emit_families`]: device + regime + a short
/// fingerprint disambiguator (two measured tables on one device are
/// different environments).
pub fn env_slug(env: &InferenceEnv) -> String {
    let fp = store::env_fingerprint(env);
    let clean: String = env
        .device_name()
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
        .collect();
    format!("{clean}_{}_{}", env.regime().name(), &fp[..8])
}

/// Pipeline stage identifiers for progress reporting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// calibration Hessian capture
    Capture,
    /// per-module OBS database builds
    BuildDbs,
    /// SPDY profile search
    Solve,
    /// profile application (masks + OBS-updated weights)
    Apply,
    /// distillation fine-tune (end of one gradual stage)
    Finetune,
    /// family manifest emission
    EmitFamily,
}

impl Stage {
    /// Human-readable stage name.
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Capture => "capture",
            Stage::BuildDbs => "build-dbs",
            Stage::Solve => "solve",
            Stage::Apply => "apply",
            Stage::Finetune => "finetune",
            Stage::EmitFamily => "emit-family",
        }
    }
}

/// One progress event, delivered to the session's hook.
#[derive(Clone, Debug)]
pub struct Progress {
    /// which stage finished
    pub stage: Stage,
    /// gradual stage index (0 for one-shot chains)
    pub stage_idx: usize,
    /// speedup target, where the stage has one
    pub target: Option<f64>,
    /// true when the artifact was restored from a checkpoint
    pub loaded: bool,
}

type Hook = Box<dyn Fn(&Progress) + Send + Sync>;

/// Ready-made progress hook: one stdout line per completed stage (what
/// the CLI and experiment drivers attach).
pub fn stdout_progress() -> impl Fn(&Progress) + Send + Sync {
    |p: &Progress| {
        let how = if p.loaded { "loaded from checkpoint" } else { "computed" };
        match p.target {
            Some(t) => {
                println!("[session] stage {} ({t:.1}x) {}: {how}", p.stage_idx, p.stage.name())
            }
            None => println!("[session] stage {} {}: {how}", p.stage_idx, p.stage.name()),
        }
    }
}

/// Builder for a [`CompressionSession`]. An [`InferenceEnv`] is the one
/// mandatory ingredient — the session refuses to open without knowing
/// what it is compressing *for*.
pub struct SessionBuilder<'e> {
    engine: &'e Engine,
    model: String,
    task: String,
    env: Option<InferenceEnv>,
    targets: Vec<f64>,
    prune: PruneCfg,
    train: Option<TrainCfg>,
    teacher: Option<Vec<f32>>,
    dir: Option<PathBuf>,
    hook: Option<Hook>,
}

impl<'e> SessionBuilder<'e> {
    /// Target inference environment (required).
    pub fn with_env(mut self, env: InferenceEnv) -> Self {
        self.env = Some(env);
        self
    }

    /// Speedup (or sparsity-factor) targets for [`CompressionSession::run`].
    pub fn with_targets(mut self, targets: &[f64]) -> Self {
        self.targets = targets.to_vec();
        self
    }

    /// Pruning configuration (calibration size, SPDY iterations, mode).
    pub fn with_prune_cfg(mut self, cfg: PruneCfg) -> Self {
        self.prune = cfg;
        self
    }

    /// Fine-tune configuration for the gradual stages; without one,
    /// [`CompressionSession::run`] prunes one-shot per target.
    pub fn with_train_cfg(mut self, cfg: TrainCfg) -> Self {
        self.train = Some(cfg);
        self
    }

    /// Dense-teacher parameters for token/logit distillation.
    pub fn with_teacher(mut self, params: Vec<f32>) -> Self {
        self.teacher = Some(params);
        self
    }

    /// Attach a checkpoint directory: completed stages persist there
    /// and a re-opened session resumes from them.
    pub fn checkpoint_to(mut self, dir: impl Into<PathBuf>) -> Self {
        self.dir = Some(dir.into());
        self
    }

    /// Progress hook, called once per completed (or loaded) stage.
    pub fn on_progress(mut self, hook: impl Fn(&Progress) + Send + Sync + 'static) -> Self {
        self.hook = Some(Box::new(hook));
        self
    }

    /// Validate and open the session. With a checkpoint directory this
    /// also pins the environment: the directory records every env it
    /// has certified against (`env.json` for the first, plus one
    /// `env_<fp>.json` per env), and resuming with an env the
    /// directory has never seen is an error, not a silent
    /// re-certification — open with a recorded env and call
    /// [`CompressionSession::retarget`] to add a new one.
    pub fn open(self) -> Result<CompressionSession<'e>> {
        let env = self.env.ok_or_else(|| {
            anyhow!("session for {}/{} needs an InferenceEnv (use with_env)", self.model, self.task)
        })?;
        let minfo = self.engine.manifest.model(&self.model).clone();
        let tinfo = self.engine.manifest.task(&self.model, &self.task).clone();
        let env_fp = store::env_fingerprint(&env);
        if let Some(dir) = &self.dir {
            let primary = dir.join("env.json");
            let pinned = dir.join(format!("env_{env_fp}.json"));
            if !primary.exists() {
                env.save(&primary)?;
                env.save(&pinned)?;
            } else if !pinned.exists() {
                let prev = InferenceEnv::load(&primary)?;
                if prev != env {
                    return Err(anyhow!(
                        "session dir {dir:?} was created for {} and has no record of {}; \
                         open with a recorded env and retarget(), or use a fresh directory",
                        prev.describe(),
                        env.describe()
                    ));
                }
                env.save(&pinned)?;
            }
        }
        Ok(CompressionSession {
            engine: self.engine,
            model: self.model,
            task: self.task,
            env,
            env_fp,
            targets: self.targets,
            prune: self.prune,
            train: self.train,
            teacher: self.teacher,
            store: StageStore::new(self.dir),
            hook: self.hook,
            minfo,
            tinfo,
        })
    }
}

/// A typed compression run of one `(model, task)`, currently priced
/// against one [`InferenceEnv`] — retargetable mid-run, and able to
/// certify against many envs at once. See the module docs for the
/// stage flow.
pub struct CompressionSession<'e> {
    engine: &'e Engine,
    model: String,
    task: String,
    env: InferenceEnv,
    env_fp: String,
    targets: Vec<f64>,
    prune: PruneCfg,
    train: Option<TrainCfg>,
    teacher: Option<Vec<f32>>,
    store: StageStore,
    hook: Option<Hook>,
    minfo: ModelInfo,
    tinfo: TaskInfo,
}

impl<'e> CompressionSession<'e> {
    /// Start building a session for `(model, task)`.
    pub fn for_model(engine: &'e Engine, model: &str, task: &str) -> SessionBuilder<'e> {
        SessionBuilder {
            engine,
            model: model.to_string(),
            task: task.to_string(),
            env: None,
            targets: Vec::new(),
            prune: PruneCfg::default(),
            train: None,
            teacher: None,
            dir: None,
            hook: None,
        }
    }

    /// The environment this session currently compresses for.
    pub fn env(&self) -> &InferenceEnv {
        &self.env
    }

    /// Re-point the session at a new inference environment WITHOUT
    /// recapturing (ROADMAP: mid-run retargeting). Capture-side
    /// checkpoints (Hessians, databases) are env-free and keep
    /// loading; solve-side artifacts are keyed per env, so the next
    /// [`Databases::solve`]/[`CompressionSession::run`] re-runs SPDY
    /// against the new cost model while every previous env's
    /// certification stays intact on disk. The new env is recorded in
    /// the session directory so a later `open` with it resumes.
    pub fn retarget(&mut self, env: InferenceEnv) -> Result<()> {
        self.record_env(&env)?;
        self.env_fp = store::env_fingerprint(&env);
        self.env = env;
        Ok(())
    }

    /// Pin `env` in the checkpoint directory (`env_<fp>.json`; also
    /// `env.json` when it is the first env the directory sees).
    fn record_env(&self, env: &InferenceEnv) -> Result<()> {
        if let Some(dir) = self.store.dir() {
            let primary = dir.join("env.json");
            if !primary.exists() {
                env.save(&primary)?;
            }
            let pinned = dir.join(format!("env_{}.json", store::env_fingerprint(env)));
            if !pinned.exists() {
                env.save(&pinned)?;
            }
        }
        Ok(())
    }

    /// The configured gradual targets.
    pub fn targets(&self) -> &[f64] {
        &self.targets
    }

    /// `(computed, loaded)` checkpointable-artifact counts. One
    /// gradual stage produces several artifacts (hessians, databases,
    /// profile, stage result), so a fresh run counts more `computed`
    /// than it has targets, while a full resume loads only the
    /// whole-stage results; the invariant to assert on is
    /// `computed == 0` for a fully-resumed run.
    pub fn counters(&self) -> (usize, usize) {
        self.store.counters()
    }

    /// Dense-model cost under this session's env and target mode.
    pub fn dense_cost(&self) -> f64 {
        pipeline::dense_cost(&self.env, &self.minfo, self.prune.target_mode)
    }

    fn emit(&self, stage: Stage, idx: usize, target: Option<f64>, loaded: bool) {
        if let Some(h) = &self.hook {
            h(&Progress { stage, stage_idx: idx, target, loaded });
        }
    }

    /// Checkpoint fingerprint for `state` under THIS session's knobs:
    /// the model state plus an encoding of the prune/train configs and
    /// the distillation teacher. Re-running with different flags over
    /// the same session dir therefore recomputes instead of silently
    /// reusing artifacts produced under the old configuration.
    fn stage_fp(&self, state: &ModelState) -> String {
        let mut ctxt = format!("{:?}|{:?}", self.prune, self.train).into_bytes();
        if let Some(t) = &self.teacher {
            ctxt.extend(t.iter().flat_map(|x| x.to_le_bytes()));
        }
        store::fingerprint_with(state, &ctxt)
    }

    /// Stage 1: accumulate calibration Hessians through `state`.
    pub fn capture<'s>(&'s self, state: &ModelState, data: &Dataset) -> Result<Captured<'s, 'e>> {
        self.capture_stage(state, data, 0)
    }

    fn capture_stage<'s>(
        &'s self,
        state: &ModelState,
        data: &Dataset,
        idx: usize,
    ) -> Result<Captured<'s, 'e>> {
        let fp = self.stage_fp(state);
        let (hessians, loaded) = self.store.load_or_compute(
            &format!("s{idx}_hessians.bin"),
            |p| store::load_hessians(p, &fp),
            |p, hs| store::save_hessians(p, &fp, hs),
            || pipeline::capture_hessians(self.engine, state, data, self.prune.calib_samples),
        )?;
        self.emit(Stage::Capture, idx, None, loaded);
        Ok(Captured { sess: self, idx, fp, state: state.clone(), hessians })
    }

    /// One-shot pruning to `target`: capture → build → solve → apply,
    /// mutating `state` in place (paper §4.3 post-training mode).
    pub fn oneshot(
        &self,
        state: &mut ModelState,
        data: &Dataset,
        target: f64,
    ) -> Result<PruneReport> {
        let dense = self.dense_cost();
        let variant = self
            .capture(state, data)?
            .build_dbs()?
            .solve_with_dense_cost(data, target, dense)?
            .apply()?;
        *state = variant.state;
        Ok(variant.report)
    }

    /// Gradual pruning across all configured targets (paper Fig. 1):
    /// per target, the full stage chain plus distillation fine-tuning,
    /// checkpointed as a unit so a resumed session fast-forwards
    /// through finished stages.
    pub fn run(&self, teacher: ModelState, data: &Dataset) -> Result<Vec<StageResult>> {
        if self.targets.is_empty() {
            return Err(anyhow!("session has no targets (use with_targets)"));
        }
        let dense = self.dense_cost();
        let mut trainer = Trainer::new(self.engine, self.tinfo.n_params, self.teacher.clone());
        let mut state = teacher;
        let mut out = Vec::new();
        for (i, &target) in self.targets.iter().enumerate() {
            // whole-stage results depend on the env (the chosen profile
            // does), so both key and fingerprint carry the env half
            let fp = solve_fingerprint(&self.stage_fp(&state), &self.env_fp);
            let state_key = format!("s{i}_state_{}.zlm", self.env_fp);
            let trainer_ref = &mut trainer;
            let state_ref = &state;
            let ((st, report, loss), loaded) = self.store.load_or_compute(
                &format!("s{i}_report_{}.json", self.env_fp),
                |p| load_stage_result(p, &state_key, &fp, target),
                |p, v: &(ModelState, PruneReport, f64)| save_stage_result(p, &state_key, &fp, v),
                || {
                    let variant = self
                        .capture_stage(state_ref, data, i)?
                        .build_dbs()?
                        .solve_with_dense_cost(data, target, dense)?
                        .apply()?;
                    let mut st = variant.state;
                    let report = variant.report;
                    let loss = match &self.train {
                        Some(tc) => {
                            trainer_ref.reset_moments();
                            trainer_ref.train(&mut st, data, tc)?
                        }
                        None => f64::NAN,
                    };
                    Ok((st, report, loss))
                },
            )?;
            self.emit(Stage::Finetune, i, Some(target), loaded);
            out.push(StageResult { report, state: st.clone(), final_train_loss: loss });
            state = st;
        }
        Ok(out)
    }

    /// Final stage: record the certified family under `dir` (manifest +
    /// per-member checkpoints) for `serve-family` and the coordinator.
    /// The manifest embeds this session's env, so serving tools price
    /// admission with the exact value the family was certified against
    /// instead of re-measuring.
    pub fn emit_family(
        &self,
        dense: &ModelState,
        stages: &[StageResult],
        dir: &Path,
    ) -> Result<FamilyManifest> {
        let fam = pipeline::emit_family(&self.env, dense, stages, dir)?;
        self.emit(Stage::EmitFamily, self.targets.len(), None, false);
        Ok(fam)
    }

    /// One capture → N certified families (the paper's "any given
    /// inference environment" claim made operational). Capture and
    /// database build run — or load from checkpoints — exactly once;
    /// each env in `envs` then gets the full SPDY solve + apply +
    /// manifest emission for every configured target, fanned out in
    /// parallel on the global pool. Families land under
    /// `base/<env_slug>/family.json`, each manifest embedding the env
    /// it was certified against. Post-training mode: members are
    /// one-shot variants of `state`, not fine-tuned. The repro harness
    /// (`ziplm repro`, DESIGN.md §11) drives its full-mode scenario
    /// matrix through this entry point — one capture, every env axis.
    pub fn emit_families(
        &self,
        state: &ModelState,
        data: &Dataset,
        envs: &[InferenceEnv],
        base: &Path,
    ) -> Result<Vec<FamilyManifest>> {
        if envs.is_empty() {
            return Err(anyhow!("emit_families needs at least one env"));
        }
        if self.targets.is_empty() {
            return Err(anyhow!("session has no targets (use with_targets)"));
        }
        for env in envs {
            self.record_env(env)?;
        }
        // register every certifying env under `base/envs/` so later
        // runs can `--retarget <slug>` without digging into manifests
        let reg = registry::EnvRegistry::new(base.join("envs"));
        for env in envs {
            reg.register(env)?;
        }
        let dbs_stage = self.capture(state, data)?.build_dbs()?;
        let stage_fp = dbs_stage.fp.clone();
        let (state0, dbs) = (dbs_stage.state, dbs_stage.dbs);
        let outs = parallel_tasks(envs.len(), |e| -> Result<FamilyManifest> {
            let env = &envs[e];
            self.emit_family_for_env(env, &stage_fp, &state0, &dbs, data, &base.join(env_slug(env)))
        });
        outs.into_iter().collect()
    }

    /// Solve + apply every target against one env over prebuilt
    /// databases, then write that env's family. The solve artifacts go
    /// through the same per-env checkpoint keys the single-env path
    /// uses, so a later session pinned to this env resumes from them.
    fn emit_family_for_env(
        &self,
        env: &InferenceEnv,
        stage_fp: &str,
        state0: &ModelState,
        dbs: &[ModuleDb],
        data: &Dataset,
        dir: &Path,
    ) -> Result<FamilyManifest> {
        let env_fp = store::env_fingerprint(env);
        let dense_cost = pipeline::dense_cost(env, &self.minfo, self.prune.target_mode);
        let problem = pipeline::spdy_problem(dbs, env, &self.minfo, self.prune.target_mode);
        let mut stages = Vec::with_capacity(self.targets.len());
        for (k, &target) in self.targets.iter().enumerate() {
            let budget = dense_cost / target;
            pipeline::check_budget(&problem, target, budget)
                .map_err(|e| anyhow!("{e} (on {})", env.describe()))?;
            let fp = solve_fingerprint(stage_fp, &env_fp);
            let (sol, loaded) = self.store.load_or_compute(
                &solve_key(0, &env_fp, target),
                |p| store::load_profile(p, &fp, target),
                |p, v: &(Vec<usize>, f64)| store::save_profile(p, &fp, target, &v.0, v.1),
                || {
                    let out = pipeline::solve_profile(
                        self.engine,
                        state0,
                        data,
                        dbs,
                        &problem,
                        budget,
                        &self.prune,
                        &self.minfo,
                        &self.tinfo,
                    )?;
                    Ok((out.profile, out.best_loss))
                },
            )?;
            self.emit(Stage::Solve, k, Some(target), loaded);
            let mut st = state0.clone();
            let choice_problem = ChoiceProblem::from_spdy(&problem);
            pipeline::apply_choices(&mut st, dbs, &choice_problem, &sol.0, &self.minfo, &self.tinfo)?;
            let layer_profile = problem.as_layer_profile(&sol.0);
            let est = pipeline::certified_est(
                env,
                &problem,
                &sol.0,
                &layer_profile,
                dense_cost,
                self.prune.target_mode,
                &self.minfo,
            );
            self.emit(Stage::Apply, k, Some(target), false);
            stages.push(StageResult {
                report: PruneReport {
                    target,
                    est_speedup: est,
                    layer_profile,
                    choices: choice_problem.profile_choices(&sol.0),
                    calib_loss: sol.1,
                    obs_dispatches: 0,
                },
                state: st,
                final_train_loss: f64::NAN,
            });
        }
        let fam = pipeline::emit_family(env, state0, &stages, dir)?;
        self.emit(Stage::EmitFamily, self.targets.len(), None, false);
        Ok(fam)
    }
}

/// Stage artifact: calibration Hessians captured through one state.
pub struct Captured<'s, 'e> {
    sess: &'s CompressionSession<'e>,
    idx: usize,
    fp: String,
    /// the state the Hessians were captured through
    pub state: ModelState,
    /// accumulated per-module XX^T
    pub hessians: Hessians,
}

impl<'s, 'e> Captured<'s, 'e> {
    /// Stage 2: build all per-module OBS databases (parallel fan-out).
    pub fn build_dbs(self) -> Result<Databases<'s, 'e>> {
        let sess = self.sess;
        let (dbs, loaded) = sess.store.load_or_compute(
            &format!("s{}_dbs.bin", self.idx),
            |p| store::load_dbs(p, &self.fp),
            |p, dbs| store::save_dbs(p, &self.fp, dbs),
            || pipeline::build_databases(sess.engine, &self.state, &self.hessians, &sess.prune),
        )?;
        sess.emit(Stage::BuildDbs, self.idx, None, loaded);
        Ok(Databases { sess, idx: self.idx, fp: self.fp, state: self.state, dbs })
    }
}

/// Stage artifact: the per-module level databases.
pub struct Databases<'s, 'e> {
    sess: &'s CompressionSession<'e>,
    idx: usize,
    fp: String,
    /// the state the databases were built from
    pub state: ModelState,
    /// all 2L module databases, (attn, fc) per layer
    pub dbs: Vec<ModuleDb>,
}

impl<'s, 'e> Databases<'s, 'e> {
    /// Stage 3: SPDY-search a profile meeting `target` under the
    /// session's dense cost.
    pub fn solve(self, data: &Dataset, target: f64) -> Result<Solved<'s, 'e>> {
        let dense = self.sess.dense_cost();
        self.solve_with_dense_cost(data, target, dense)
    }

    /// [`Databases::solve`] with an explicit dense-cost anchor (the
    /// sparsity ablation passes a parameter budget).
    pub fn solve_with_dense_cost(
        self,
        data: &Dataset,
        target: f64,
        dense_cost: f64,
    ) -> Result<Solved<'s, 'e>> {
        let sess = self.sess;
        let problem =
            pipeline::spdy_problem(&self.dbs, &sess.env, &sess.minfo, sess.prune.target_mode);
        let budget = dense_cost / target;
        pipeline::check_budget(&problem, target, budget)?;
        // per-env key + fingerprint: a retargeted session computes a
        // fresh profile here while the previous env's stays on disk
        let fp = solve_fingerprint(&self.fp, &sess.env_fp);
        let (sol, loaded) = sess.store.load_or_compute(
            &solve_key(self.idx, &sess.env_fp, target),
            |p| store::load_profile(p, &fp, target),
            |p, v: &(Vec<usize>, f64)| store::save_profile(p, &fp, target, &v.0, v.1),
            || {
                let out = pipeline::solve_profile(
                    sess.engine,
                    &self.state,
                    data,
                    &self.dbs,
                    &problem,
                    budget,
                    &sess.prune,
                    &sess.minfo,
                    &sess.tinfo,
                )?;
                Ok((out.profile, out.best_loss))
            },
        )?;
        sess.emit(Stage::Solve, self.idx, Some(target), loaded);
        Ok(Solved {
            sess,
            idx: self.idx,
            state: self.state,
            dbs: self.dbs,
            target,
            dense_cost,
            profile: sol.0,
            best_loss: sol.1,
            problem,
        })
    }
}

/// Stage artifact: a chosen SPDY profile, not yet applied.
pub struct Solved<'s, 'e> {
    sess: &'s CompressionSession<'e>,
    idx: usize,
    state: ModelState,
    dbs: Vec<ModuleDb>,
    target: f64,
    dense_cost: f64,
    /// chosen level index per module
    pub profile: Vec<usize>,
    /// calibration loss of the chosen profile
    pub best_loss: f64,
    problem: SpdyProblem,
}

impl Solved<'_, '_> {
    /// Stage 4: apply the profile (snapshot weights + kill masks) and
    /// certify the resulting variant.
    pub fn apply(self) -> Result<Variant> {
        let sess = self.sess;
        let mut state = self.state;
        let choice_problem = ChoiceProblem::from_spdy(&self.problem);
        pipeline::apply_choices(
            &mut state,
            &self.dbs,
            &choice_problem,
            &self.profile,
            &sess.minfo,
            &sess.tinfo,
        )?;
        let layer_profile = self.problem.as_layer_profile(&self.profile);
        let est = pipeline::certified_est(
            &sess.env,
            &self.problem,
            &self.profile,
            &layer_profile,
            self.dense_cost,
            sess.prune.target_mode,
            &sess.minfo,
        );
        let report = PruneReport {
            target: self.target,
            est_speedup: est,
            layer_profile,
            choices: choice_problem.profile_choices(&self.profile),
            calib_loss: self.best_loss,
            obs_dispatches: 0,
        };
        sess.emit(Stage::Apply, self.idx, Some(self.target), false);
        Ok(Variant { state, report })
    }
}

/// Stage artifact: one certified compressed variant.
pub struct Variant {
    /// the pruned model state
    pub state: ModelState,
    /// the certification record (target, est. speedup, anatomy)
    pub report: PruneReport,
}

// --------------------------------------------- whole-stage checkpoints

fn load_stage_result(
    report_path: &Path,
    state_key: &str,
    fp: &str,
    target: f64,
) -> Option<(ModelState, PruneReport, f64)> {
    let j = Json::parse(&std::fs::read_to_string(report_path).ok()?).ok()?;
    if j.get("kind")?.as_str()? != "stage"
        || j.get("fingerprint")?.as_str()? != fp
        || j.get("target")?.as_f64()? != target
    {
        return None;
    }
    let state = ModelState::load(&report_path.with_file_name(state_key)).ok()?;
    let layer_profile: Vec<(usize, usize)> = j
        .get("profile")?
        .as_arr()?
        .iter()
        .map(|e| Some((e.idx(0)?.as_usize()?, e.idx(1)?.as_usize()?)))
        .collect::<Option<Vec<_>>>()?;
    let report = PruneReport {
        target,
        est_speedup: j.get("est_speedup")?.as_f64()?,
        choices: CompressionProfile::from_layer_profile(&layer_profile),
        layer_profile,
        calib_loss: j.get("calib_loss").and_then(Json::as_f64).unwrap_or(f64::INFINITY),
        obs_dispatches: 0,
    };
    let loss = j.get("train_loss").and_then(Json::as_f64).unwrap_or(f64::NAN);
    Some((state, report, loss))
}

fn save_stage_result(
    report_path: &Path,
    state_key: &str,
    fp: &str,
    v: &(ModelState, PruneReport, f64),
) -> Result<()> {
    let (state, report, loss) = v;
    state.save(&report_path.with_file_name(state_key))?;
    let finite = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
    let j = Json::obj(vec![
        ("kind", Json::Str("stage".into())),
        ("fingerprint", Json::Str(fp.to_string())),
        ("target", Json::Num(report.target)),
        ("est_speedup", Json::Num(report.est_speedup)),
        ("calib_loss", finite(report.calib_loss)),
        (
            "profile",
            Json::Arr(
                report
                    .layer_profile
                    .iter()
                    .map(|&(h, f)| Json::Arr(vec![Json::Num(h as f64), Json::Num(f as f64)]))
                    .collect(),
            ),
        ),
        ("train_loss", finite(*loss)),
    ]);
    std::fs::write(report_path, j.to_pretty())?;
    Ok(())
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn stage_result_roundtrip_gates_on_fingerprint_and_target() {
        let dir = std::env::temp_dir().join("ziplm_session_stage");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (_mi, _ti, st) = crate::models::tests_support::mini_state();
        let report = PruneReport {
            target: 2.0,
            est_speedup: 2.13,
            layer_profile: vec![(2, 6), (1, 4)],
            choices: CompressionProfile::from_layer_profile(&[(2, 6), (1, 4)]),
            calib_loss: 0.5,
            obs_dispatches: 0,
        };
        let rp = dir.join("s0_report.json");
        save_stage_result(&rp, "s0_state.zlm", "fp0", &(st.clone(), report.clone(), 0.25))
            .unwrap();
        let (st2, rep2, loss) = load_stage_result(&rp, "s0_state.zlm", "fp0", 2.0).expect("load");
        assert_eq!(st2.params, st.params);
        assert_eq!(rep2.layer_profile, report.layer_profile);
        assert_eq!(rep2.est_speedup, report.est_speedup);
        assert_eq!(loss, 0.25);
        // wrong fingerprint or target → miss, never a stale load
        assert!(load_stage_result(&rp, "s0_state.zlm", "other", 2.0).is_none());
        assert!(load_stage_result(&rp, "s0_state.zlm", "fp0", 3.0).is_none());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn stage_result_nan_train_loss_roundtrips_as_nan() {
        let dir = std::env::temp_dir().join("ziplm_session_nan");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let (_mi, _ti, st) = crate::models::tests_support::mini_state();
        let report = PruneReport {
            target: 1.5,
            est_speedup: 1.5,
            layer_profile: vec![(2, 8)],
            choices: CompressionProfile::from_layer_profile(&[(2, 8)]),
            calib_loss: f64::INFINITY,
            obs_dispatches: 0,
        };
        let rp = dir.join("s0_report.json");
        save_stage_result(&rp, "s0_state.zlm", "fp", &(st, report, f64::NAN)).unwrap();
        let (_, rep2, loss) = load_stage_result(&rp, "s0_state.zlm", "fp", 1.5).expect("load");
        assert!(loss.is_nan());
        assert!(rep2.calib_loss.is_infinite());
        let _ = std::fs::remove_dir_all(dir);
    }
}
