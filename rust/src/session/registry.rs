//! On-disk environment registry: `envs/<slug>.json`, fingerprint-
//! checked (DESIGN.md §12).
//!
//! Every certified family names the [`InferenceEnv`] it was solved
//! against, but until now that env lived only inside the manifest.
//! The registry gives each env a stable, human-usable address — the
//! same `{device}_{regime}_{fp8}` slug the multi-env session uses for
//! its per-env output directories ([`super::env_slug`]) — so CLI flows
//! can say `prune-gradual --retarget gpu-sim_throughput_1a2b3c4d`
//! instead of shipping JSON paths around. Registration is idempotent
//! and tamper-evident: re-registering the same env is a no-op, while a
//! slug collision with DIFFERENT env content (a hand-edited file, a
//! fingerprint truncation collision) is an error rather than a silent
//! overwrite — the fingerprint covers the full serialized env, exactly
//! like the session's `env_<fp>.json` pinning.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::{env_slug, store::env_fingerprint};
use crate::env::InferenceEnv;

/// A directory of `<slug>.json` environment files.
#[derive(Clone, Debug)]
pub struct EnvRegistry {
    dir: PathBuf,
}

impl EnvRegistry {
    /// Registry rooted at `dir` (created lazily on first register).
    pub fn new(dir: impl Into<PathBuf>) -> EnvRegistry {
        EnvRegistry { dir: dir.into() }
    }

    /// Registry root.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Register `env` under its slug and return the slug.
    ///
    /// Idempotent: an existing file with the same fingerprint is left
    /// untouched; an existing file with DIFFERENT content is an error.
    pub fn register(&self, env: &InferenceEnv) -> Result<String> {
        let slug = env_slug(env);
        let path = self.dir.join(format!("{slug}.json"));
        if path.exists() {
            let have = InferenceEnv::load(&path)
                .with_context(|| format!("registry: unreadable {}", path.display()))?;
            if env_fingerprint(&have) != env_fingerprint(env) {
                return Err(anyhow!(
                    "registry: slug `{slug}` already maps to a different env ({})",
                    path.display()
                ));
            }
            return Ok(slug);
        }
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("registry: create {}", self.dir.display()))?;
        env.save(&path)?;
        Ok(slug)
    }

    /// Resolve `name` to an env: a path to a JSON file (absolute or
    /// relative, detected by existence or a `.json` suffix), or a
    /// registered slug looked up under the registry root. A slug hit
    /// is verified against the loaded env's own slug, so a renamed
    /// file cannot impersonate another env.
    pub fn resolve(&self, name: &str) -> Result<InferenceEnv> {
        let direct = Path::new(name);
        if direct.exists() || name.ends_with(".json") {
            return InferenceEnv::load(direct)
                .with_context(|| format!("registry: load env file {name}"));
        }
        let path = self.dir.join(format!("{name}.json"));
        let env = InferenceEnv::load(&path).with_context(|| {
            format!(
                "registry: `{name}` is neither an env file nor a slug under {}",
                self.dir.display()
            )
        })?;
        let slug = env_slug(&env);
        if slug != name {
            return Err(anyhow!(
                "registry: {} claims slug `{name}` but its content fingerprints to `{slug}`",
                path.display()
            ));
        }
        Ok(env)
    }

    /// All registered slugs, sorted (for `ziplm adapt` listings).
    pub fn slugs(&self) -> Vec<String> {
        let mut out: Vec<String> = std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| {
                        let name = e.file_name().to_string_lossy().into_owned();
                        name.strip_suffix(".json").map(str::to_string)
                    })
                    .collect()
            })
            .unwrap_or_default();
        out.sort();
        out
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::latency::LatencyTable;

    fn env(overhead: f64) -> InferenceEnv {
        InferenceEnv::measured(LatencyTable {
            model: "m".into(),
            device: "reg sim!".into(),
            regime: "throughput".into(),
            attn: vec![0.0, 1e-3, 2e-3],
            mlp: vec![(8, 4e-3), (0, 0.0)],
            overhead,
        })
        .unwrap()
    }

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ziplm-registry-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn register_resolve_roundtrip_and_idempotence() {
        let dir = tmp("rt");
        let reg = EnvRegistry::new(&dir);
        let e = env(1e-3);
        let slug = reg.register(&e).unwrap();
        assert!(slug.starts_with("reg-sim-_throughput_"), "{slug}");
        // second registration of the identical env is a no-op
        assert_eq!(reg.register(&e).unwrap(), slug);
        let back = reg.resolve(&slug).unwrap();
        assert_eq!(env_fingerprint(&back), env_fingerprint(&e));
        assert_eq!(reg.slugs(), vec![slug.clone()]);
        // path form resolves too
        let by_path = reg.resolve(dir.join(format!("{slug}.json")).to_str().unwrap()).unwrap();
        assert_eq!(env_fingerprint(&by_path), env_fingerprint(&e));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn collisions_and_imposters_are_errors() {
        let dir = tmp("col");
        let reg = EnvRegistry::new(&dir);
        let slug = reg.register(&env(1e-3)).unwrap();
        // different env forced under the same slug file → error
        env(9e-3).save(&dir.join(format!("{slug}.json"))).unwrap();
        assert!(reg.register(&env(1e-3)).is_err(), "tampered file must not pass");
        // a renamed env file cannot impersonate a slug
        env(9e-3).save(&dir.join("stolen-name.json")).unwrap();
        let err = reg.resolve("stolen-name").unwrap_err().to_string();
        assert!(err.contains("fingerprints to"), "{err}");
        // unknown slug → a helpful error
        assert!(reg.resolve("no-such-slug").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
