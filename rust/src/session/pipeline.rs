//! Pipeline kernels: the ZipLM stages (paper Fig. 1) as env-typed free
//! functions.
//!
//! These are the algorithmic bodies behind [`super::CompressionSession`]
//! — Hessian capture, parallel database builds, SPDY assembly/search,
//! profile application, the gradual driver, and family emission. The
//! session stages wrap them with checkpointing and progress hooks;
//! the straight-line drivers here ([`prune_to_target`], [`gradual`])
//! are the checkpoint-free equivalents the legacy-vs-session
//! equivalence tests drive. Every latency question goes through one
//! [`InferenceEnv`] — the same value the family coordinator later
//! routes with. Note the env split: [`capture_hessians`] and
//! [`build_databases`] never see an env (their artifacts retarget for
//! free), while [`spdy_problem`] onward price against exactly one.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::compress::{Choice, ChoiceProblem, CompressionProfile, LayerChoice, QuantScheme};
use crate::data::Dataset;
use crate::env::{CostModel, InferenceEnv};
use crate::eval::{calib_loss, mask_literals};
use crate::latency::low_rank_ffn_width;
use crate::models::family::{FamilyManifest, FamilyMember};
use crate::models::ModelState;
use crate::pruner::{CompoundCfg, Hessians, PruneCfg, PruneReport, StageResult, TargetMode};
use crate::quant;
use crate::runtime::{lit_f32_shaped, lit_i32, lit_to_f32, Engine, ModelInfo, TaskInfo};
use crate::spdy::{self, LevelOpt, ModuleLevels, SearchCfg, SpdyProblem};
use crate::tensor::{linalg, Tensor};
use crate::train::{TrainCfg, Trainer};
use crate::util::threadpool::parallel_tasks;
use crate::ziplm::{
    assemble_hessian, build_module_db, build_module_db_masked, damped_hessian, relative_error,
    HloBackend, ModuleDb, NativeBackend, ObsOps,
};

/// Run the calib artifact over `n_samples` and accumulate XX^T.
pub fn capture_hessians(
    engine: &Engine,
    state: &ModelState,
    data: &Dataset,
    n_samples: usize,
) -> Result<Hessians> {
    let minfo = engine.manifest.model(&state.model).clone();
    let tinfo = engine.manifest.task(&state.model, &state.task).clone();
    let b = engine.manifest.batch_calib;
    let art = format!("{}__{}__calib", state.model, state.task);
    let (hm, fm) = mask_literals(state)?;
    let params = lit_f32_shaped(&[tinfo.n_params], &state.params)?;
    let da = minfo.d_attn();
    let f = minfo.d_ff;
    let l = minfo.n_layers;
    let mut attn = vec![Tensor::zeros(&[da, da]); l];
    let mut ffn = vec![Tensor::zeros(&[f, f]); l];
    let mut i = 0;
    while i < n_samples.max(b) {
        let idxs: Vec<usize> = (i..i + b).collect();
        let (ids, _) = data.batch(&idxs);
        let out = engine.run(
            &art,
            &[params.clone(), lit_i32(&[b, data.seq_len], &ids)?, hm.clone(), fm.clone()],
        )?;
        let ha = lit_to_f32(&out[0])?; // [L, da, da]
        let hf = lit_to_f32(&out[1])?; // [L, f, f]
        for li in 0..l {
            let sa = &ha[li * da * da..(li + 1) * da * da];
            for (dst, src) in attn[li].data.iter_mut().zip(sa) {
                *dst += src;
            }
            let sf = &hf[li * f * f..(li + 1) * f * f];
            for (dst, src) in ffn[li].data.iter_mut().zip(sf) {
                *dst += src;
            }
        }
        i += b;
    }
    Ok(Hessians { attn, ffn })
}

/// Build all 2L module databases. Module order: (attn, fc) per layer.
///
/// Modules are independent once the per-module Hessian is accumulated,
/// so every (layer, attn|fc) build — including its O(d³) Hessian
/// inversion — runs as one [`parallel_tasks`] job, capped at the
/// hardware parallelism: a full per-layer database build saturates
/// the machine instead of running layer-by-layer.
pub fn build_databases(
    engine: &Engine,
    state: &ModelState,
    hs: &Hessians,
    cfg: &PruneCfg,
) -> Result<Vec<ModuleDb>> {
    let minfo = engine.manifest.model(&state.model).clone();
    let tinfo = engine.manifest.task(&state.model, &state.task).clone();
    let n_modules = 2 * minfo.n_layers;
    let dbs = parallel_tasks(n_modules, |m| -> Result<ModuleDb> {
        let (l, is_attn) = (m / 2, m % 2 == 0);
        if is_attn {
            let w0 = state.attn_w_paper(&tinfo, l)?;
            let (h, hinv) = assemble_hessian(&hs.attn[l], cfg.damp_frac)?;
            let cur_heads = state.masks.heads_alive(l);
            let levels: Vec<usize> = (0..=cur_heads).rev().collect();
            if cfg.use_hlo {
                let mut ops = HloBackend::attn(engine, &state.model)?;
                build_db_with_mask(&mut ops, l, true, &w0, &hinv, &h, &levels, state.masks.head_row(l))
            } else {
                let mut ops = NativeBackend::new(minfo.d_head);
                build_db_with_mask(&mut ops, l, true, &w0, &hinv, &h, &levels, state.masks.head_row(l))
            }
        } else {
            let w0 = state.fc_w_paper(&tinfo, l)?;
            let (h, hinv) = assemble_hessian(&hs.ffn[l], cfg.damp_frac)?;
            let cur = state.masks.ffn_alive(l);
            let mut levels: Vec<usize> = vec![cur];
            levels.extend(minfo.ffn_ladder.iter().copied().filter(|&x| x < cur));
            if cfg.use_hlo {
                let mut ops = HloBackend::fc(engine, &state.model)?;
                build_db_with_mask(&mut ops, l, false, &w0, &hinv, &h, &levels, state.masks.ffn_row(l))
            } else {
                let mut ops = NativeBackend::new(1);
                build_db_with_mask(&mut ops, l, false, &w0, &hinv, &h, &levels, state.masks.ffn_row(l))
            }
        }
    });
    dbs.into_iter().collect()
}

/// build_module_db wrapper that respects an existing structural mask
/// (gradual pruning continues from the current model).
#[allow(clippy::too_many_arguments)]
fn build_db_with_mask(
    ops: &mut dyn ObsOps,
    layer: usize,
    is_attn: bool,
    w0: &Tensor,
    hinv: &Tensor,
    h: &Tensor,
    levels: &[usize],
    mask_row: &[f32],
) -> Result<ModuleDb> {
    let g = ops.group();
    let n_structs = w0.cols() / g;
    let already_dead: Vec<usize> =
        (0..n_structs).filter(|&j| mask_row.get(j).copied().unwrap_or(1.0) == 0.0).collect();
    if already_dead.is_empty() {
        return build_module_db(ops, layer, is_attn, w0, hinv, h, levels);
    }
    // Re-anchor: treat currently-alive structures as the dense level.
    let mut db = build_module_db_masked(ops, layer, is_attn, w0, hinv, h, levels, &already_dead)?;
    for lvl in &mut db.levels {
        // make dead lists absolute (include pre-existing dead)
        let mut dead = already_dead.clone();
        dead.extend(lvl.dead.iter().copied());
        lvl.dead = dead;
    }
    Ok(db)
}

/// Module parameter counts for sparsity-target mode (Fig. 4).
pub fn module_params(minfo: &ModelInfo, is_attn: bool, remaining: usize) -> f64 {
    if is_attn {
        // q,k,v,o weights+biases per head
        (remaining * minfo.d_head * minfo.d_model * 4 + remaining * minfo.d_head * 3) as f64
    } else {
        (remaining * minfo.d_model * 2 + remaining) as f64
    }
}

/// Dense-model cost under the env's pricing: end-to-end latency in
/// speedup mode, total prunable parameters in sparsity mode. The
/// anchor every speedup/sparsity target divides.
pub fn dense_cost(env: &InferenceEnv, minfo: &ModelInfo, mode: TargetMode) -> f64 {
    match mode {
        TargetMode::Speedup => env.dense_time(minfo.n_layers),
        TargetMode::Sparsity => (0..minfo.n_layers)
            .map(|_| module_params(minfo, true, minfo.n_heads) + module_params(minfo, false, minfo.d_ff))
            .sum(),
    }
}

/// Reject a target whose budget not even the cheapest configuration
/// meets. ONE definition shared by every solve path (one-shot,
/// gradual, retargeted, multi-env) so the feasibility contract cannot
/// drift between them.
pub fn check_budget(problem: &SpdyProblem, target: f64, budget: f64) -> Result<()> {
    if problem.min_cost() > budget {
        return Err(anyhow!(
            "target {target}x infeasible: min cost {:.3e} > budget {:.3e}",
            problem.min_cost(),
            budget
        ));
    }
    Ok(())
}

/// Certified-speedup estimate for a chosen profile. ONE definition
/// shared by every solve path — in speedup mode the profile's priced
/// cost against the dense anchor, in sparsity mode the env speedup the
/// chosen sparsity happens to deliver — so `emit_families`,
/// `retarget`-ed solves, and the straight-line drivers can never
/// certify the same profile differently.
pub fn certified_est(
    env: &InferenceEnv,
    problem: &SpdyProblem,
    profile: &[usize],
    layer_profile: &[(usize, usize)],
    dense_cost: f64,
    mode: TargetMode,
    minfo: &ModelInfo,
) -> f64 {
    match mode {
        TargetMode::Speedup => dense_cost / problem.profile_cost(profile),
        TargetMode::Sparsity => env.dense_time(minfo.n_layers) / env.model_time(layer_profile),
    }
}

/// Assemble the SPDY problem from databases + the environment's costs.
pub fn spdy_problem(
    dbs: &[ModuleDb],
    env: &InferenceEnv,
    minfo: &ModelInfo,
    mode: TargetMode,
) -> SpdyProblem {
    let modules = dbs
        .iter()
        .map(|db| ModuleLevels {
            layer: db.layer,
            is_attn: db.is_attn,
            options: db
                .levels
                .iter()
                .map(|lvl| LevelOpt {
                    remaining: lvl.remaining,
                    cost: match mode {
                        TargetMode::Speedup => {
                            if db.is_attn {
                                env.attn_time(lvl.remaining)
                            } else {
                                env.mlp_time(lvl.remaining)
                            }
                        }
                        TargetMode::Sparsity => module_params(minfo, db.is_attn, lvl.remaining),
                    },
                    prior: lvl.prior,
                })
                .collect(),
        })
        .collect();
    SpdyProblem {
        modules,
        overhead: match mode {
            TargetMode::Speedup => env.overhead(),
            TargetMode::Sparsity => 0.0,
        },
    }
}

/// Apply a chosen raw level-index profile: write snapshot weights +
/// kill masks.
#[deprecated(
    note = "raw `Vec<usize>` profile surface: use `apply_choices` with a typed \
            `compress::ChoiceProblem` (a prune-only lattice applies bit-identically; \
            DESIGN.md §13)"
)]
pub fn apply_profile(
    state: &mut ModelState,
    dbs: &[ModuleDb],
    profile: &[usize],
    minfo: &ModelInfo,
    tinfo: &TaskInfo,
) -> Result<()> {
    apply_level_indices(state, dbs, profile, minfo, tinfo)
}

/// Level-index application body shared by the deprecated raw shim and
/// the prune arm of [`apply_choices`]'s search loop.
fn apply_level_indices(
    state: &mut ModelState,
    dbs: &[ModuleDb],
    profile: &[usize],
    minfo: &ModelInfo,
    tinfo: &TaskInfo,
) -> Result<()> {
    for (db, &li) in dbs.iter().zip(profile) {
        let lvl = &db.levels[li];
        write_module(state, db, &lvl.w, &lvl.dead, minfo, tinfo)?;
    }
    Ok(())
}

/// Write one module's weights + kill masks (the single state-mutation
/// path every apply goes through).
fn write_module(
    state: &mut ModelState,
    db: &ModuleDb,
    w: &Tensor,
    dead: &[usize],
    minfo: &ModelInfo,
    tinfo: &TaskInfo,
) -> Result<()> {
    if db.is_attn {
        state.set_attn_w_paper(tinfo, db.layer, w, dead, minfo.d_head)?;
        for &h in dead {
            state.masks.kill_head(db.layer, h);
        }
    } else {
        state.set_fc_w_paper(tinfo, db.layer, w, dead)?;
        for &c in dead {
            state.masks.kill_ffn_col(db.layer, c);
        }
    }
    Ok(())
}

/// Assemble the compound choice lattice (DESIGN.md §13): the SPDY
/// pruning options verbatim (so a prune-only lattice lowers to the
/// exact `spdy_problem` numbers), plus env-priced int8 and low-rank
/// FFN choices scored by OBS-style reconstruction error against the
/// SAME damped calibration Hessian the pruning priors used. Speedup
/// mode only — quant and low-rank don't change parameter counts, so a
/// sparsity budget has nothing to trade them against.
pub fn choice_problem(
    dbs: &[ModuleDb],
    hs: &Hessians,
    env: &InferenceEnv,
    minfo: &ModelInfo,
    cfg: &PruneCfg,
    ccfg: &CompoundCfg,
) -> Result<ChoiceProblem> {
    if cfg.target_mode != TargetMode::Speedup {
        return Err(anyhow!("compound lattice requires speedup target mode"));
    }
    let base = spdy_problem(dbs, env, minfo, cfg.target_mode);
    let mut problem = ChoiceProblem::from_spdy(&base);
    for (db, set) in dbs.iter().zip(&mut problem.modules) {
        let acc = if db.is_attn { &hs.attn[db.layer] } else { &hs.ffn[db.layer] };
        let h = damped_hessian(acc, cfg.damp_frac);
        let w0 = &db.levels[0].w;
        let dense_rem = set.dense_remaining();
        let mut extra = Vec::new();
        if ccfg.quant {
            // int8 on every prune level: the dense level becomes the
            // plain quant choice, pruned levels compose prune-then-quant
            for (li, lvl) in db.levels.iter().enumerate() {
                if lvl.remaining == 0 {
                    continue; // a dropped module has nothing to quantize
                }
                let cost = if db.is_attn {
                    env.attn_time_quant(lvl.remaining)
                } else {
                    env.mlp_time_quant(lvl.remaining)
                };
                let choice = if li == 0 {
                    LayerChoice::Quant { scheme: QuantScheme::Int8 }
                } else {
                    LayerChoice::PruneQuant { remaining: lvl.remaining, scheme: QuantScheme::Int8 }
                };
                let loss = relative_error(w0, &quant::int8_tensor(&lvl.w), &h);
                extra.push(Choice { choice, cost, loss });
            }
        }
        if !db.is_attn {
            // low-rank factorization of the stacked FFN pair: priced as
            // the dense width with equal GEMM work, scored by the
            // truncated-SVD residual's output error
            let d = w0.rows();
            let ranks = if ccfg.ranks.is_empty() {
                vec![d * 3 / 4, d / 2, d / 4]
            } else {
                ccfg.ranks.clone()
            };
            for rank in ranks {
                if rank == 0 || rank >= d {
                    continue;
                }
                let w_eff = low_rank_ffn_width(d, dense_rem, rank);
                if w_eff >= dense_rem {
                    continue; // prices no cheaper than dense
                }
                let wr = linalg::low_rank_approx(w0, rank)
                    .map_err(|e| anyhow!("low-rank score (layer {}): {e}", db.layer))?;
                extra.push(Choice {
                    choice: LayerChoice::LowRank { rank },
                    cost: env.mlp_time(w_eff),
                    loss: relative_error(w0, &wr, &h),
                });
            }
        }
        set.choices.extend(extra);
    }
    Ok(problem)
}

/// Apply a typed choice assignment: prune choices write their OBS
/// snapshot + kill masks exactly like the legacy path; quant choices
/// write the int8-requantized snapshot; low-rank choices write the
/// truncated-SVD reconstruction. The typed replacement for
/// [`apply_profile`].
pub fn apply_choices(
    state: &mut ModelState,
    dbs: &[ModuleDb],
    problem: &ChoiceProblem,
    profile: &[usize],
    minfo: &ModelInfo,
    tinfo: &TaskInfo,
) -> Result<()> {
    for ((db, set), &ci) in dbs.iter().zip(&problem.modules).zip(profile) {
        let chosen = set
            .choices
            .get(ci)
            .ok_or_else(|| anyhow!("choice index {ci} out of range (layer {})", db.layer))?;
        let level = |remaining: usize| {
            db.level(remaining).ok_or_else(|| {
                anyhow!(
                    "no snapshot at remaining {remaining} for layer {} {}",
                    db.layer,
                    if db.is_attn { "attn" } else { "ffn" }
                )
            })
        };
        match chosen.choice {
            LayerChoice::Prune { remaining } => {
                let lvl = level(remaining)?;
                write_module(state, db, &lvl.w, &lvl.dead, minfo, tinfo)?;
            }
            LayerChoice::Quant { .. } => {
                let lvl = &db.levels[0];
                write_module(state, db, &quant::int8_tensor(&lvl.w), &lvl.dead, minfo, tinfo)?;
            }
            LayerChoice::PruneQuant { remaining, .. } => {
                let lvl = level(remaining)?;
                write_module(state, db, &quant::int8_tensor(&lvl.w), &lvl.dead, minfo, tinfo)?;
            }
            LayerChoice::LowRank { rank } => {
                if db.is_attn {
                    return Err(anyhow!(
                        "low-rank choice on attention module (layer {})",
                        db.layer
                    ));
                }
                let lvl = &db.levels[0];
                let wr = linalg::low_rank_approx(&lvl.w, rank)
                    .map_err(|e| anyhow!("low-rank apply (layer {}): {e}", db.layer))?;
                write_module(state, db, &wr, &lvl.dead, minfo, tinfo)?;
            }
        }
    }
    Ok(())
}

/// Result of the outer SPDY search over one stage.
pub struct SolveOutcome {
    /// chosen level index per module
    pub profile: Vec<usize>,
    /// calibration loss of the chosen profile
    pub best_loss: f64,
    /// candidate profiles evaluated (cache misses)
    pub evals: usize,
}

/// Outer SPDY mutation search scored by REAL calibration loss: every
/// candidate the DP emits already meets the budget (the paper's
/// headline property), the search only picks the most accurate one.
#[allow(clippy::too_many_arguments)]
pub fn solve_profile(
    engine: &Engine,
    base: &ModelState,
    data: &Dataset,
    dbs: &[ModuleDb],
    problem: &SpdyProblem,
    budget: f64,
    cfg: &PruneCfg,
    minfo: &ModelInfo,
    tinfo: &TaskInfo,
) -> Result<SolveOutcome> {
    let mut evals = 0usize;
    let search_cfg = SearchCfg { iters: cfg.spdy.iters, seed: cfg.spdy.seed, ..Default::default() };
    let (profile, best_loss) = spdy::search(problem, budget, &search_cfg, |prof| {
        evals += 1;
        let mut cand = base.clone();
        if apply_level_indices(&mut cand, dbs, prof, minfo, tinfo).is_err() {
            return f64::INFINITY;
        }
        calib_loss(engine, &cand, data, cfg.calib_samples.min(128)).unwrap_or(f64::INFINITY)
    })
    .ok_or_else(|| anyhow!("SPDY found no feasible profile inside budget {budget:.3e}"))?;
    Ok(SolveOutcome { profile, best_loss, evals })
}

/// One pruning stage: Hessians → databases → SPDY → apply.
/// `dense_cost` is the dense model's cost under the env (speedup anchor).
pub fn prune_to_target(
    engine: &Engine,
    state: &mut ModelState,
    data: &Dataset,
    env: &InferenceEnv,
    dense_cost: f64,
    target: f64,
    cfg: &PruneCfg,
) -> Result<PruneReport> {
    let minfo = engine.manifest.model(&state.model).clone();
    let tinfo = engine.manifest.task(&state.model, &state.task).clone();
    let hs = capture_hessians(engine, state, data, cfg.calib_samples)?;
    let dbs = build_databases(engine, state, &hs, cfg)?;
    let problem = spdy_problem(&dbs, env, &minfo, cfg.target_mode);
    let budget = dense_cost / target;
    check_budget(&problem, target, budget)?;
    let sol = solve_profile(engine, state, data, &dbs, &problem, budget, cfg, &minfo, &tinfo)?;
    apply_level_indices(state, &dbs, &sol.profile, &minfo, &tinfo)?;
    let layer_profile = problem.as_layer_profile(&sol.profile);
    let est = certified_est(
        env,
        &problem,
        &sol.profile,
        &layer_profile,
        dense_cost,
        cfg.target_mode,
        &minfo,
    );
    crate::zlog!(
        "info",
        "pruned to {target}x: est_speedup={est:.2} profile={layer_profile:?} candidates={}",
        sol.evals
    );
    Ok(PruneReport {
        target,
        est_speedup: est,
        layer_profile,
        choices: ChoiceProblem::from_spdy(&problem).profile_choices(&sol.profile),
        calib_loss: sol.best_loss,
        obs_dispatches: 0,
    })
}

/// One compound-compression stage: Hessians → databases → choice
/// lattice → widened SPDY over choice indices → apply (DESIGN.md §13).
/// The compound sibling of [`prune_to_target`]: with the lattice
/// restricted to the prune axis it lowers to the exact same
/// `SpdyProblem`, so this degenerates to the legacy solve
/// bit-identically.
#[allow(clippy::too_many_arguments)]
pub fn compound_to_target(
    engine: &Engine,
    state: &mut ModelState,
    data: &Dataset,
    env: &InferenceEnv,
    dense_cost: f64,
    target: f64,
    cfg: &PruneCfg,
    ccfg: &CompoundCfg,
) -> Result<PruneReport> {
    let minfo = engine.manifest.model(&state.model).clone();
    let tinfo = engine.manifest.task(&state.model, &state.task).clone();
    let hs = capture_hessians(engine, state, data, cfg.calib_samples)?;
    let dbs = build_databases(engine, state, &hs, cfg)?;
    let problem = choice_problem(&dbs, &hs, env, &minfo, cfg, ccfg)?;
    let lowered = problem.lower();
    let budget = dense_cost / target;
    check_budget(&lowered, target, budget)?;
    let mut evals = 0usize;
    let search_cfg = SearchCfg { iters: cfg.spdy.iters, seed: cfg.spdy.seed, ..Default::default() };
    let (profile, best_loss) = spdy::search(&lowered, budget, &search_cfg, |prof| {
        evals += 1;
        let mut cand = state.clone();
        if apply_choices(&mut cand, &dbs, &problem, prof, &minfo, &tinfo).is_err() {
            return f64::INFINITY;
        }
        calib_loss(engine, &cand, data, cfg.calib_samples.min(128)).unwrap_or(f64::INFINITY)
    })
    .ok_or_else(|| anyhow!("compound SPDY found no feasible profile inside budget {budget:.3e}"))?;
    apply_choices(state, &dbs, &problem, &profile, &minfo, &tinfo)?;
    let layer_profile = problem.as_layer_profile(&profile);
    let est = dense_cost / problem.profile_cost(&profile);
    let choices = problem.profile_choices(&profile);
    crate::zlog!(
        "info",
        "compound to {target}x: est_speedup={est:.2} mix={:?} candidates={evals}",
        choices.axis_counts()
    );
    Ok(PruneReport {
        target,
        est_speedup: est,
        layer_profile,
        choices,
        calib_loss: best_loss,
        obs_dispatches: 0,
    })
}

/// Gradual pruning: the full family pipeline (paper Fig. 1), one stage
/// per target with distillation fine-tuning between stages. This is
/// the straight-line driver; [`super::CompressionSession::run`] is the
/// checkpointable equivalent.
#[allow(clippy::too_many_arguments)]
pub fn gradual(
    engine: &Engine,
    mut state: ModelState,
    data: &Dataset,
    env: &InferenceEnv,
    targets: &[f64],
    prune_cfg: &PruneCfg,
    train_cfg: &TrainCfg,
    teacher: Option<Vec<f32>>,
) -> Result<Vec<StageResult>> {
    let tinfo = engine.manifest.task(&state.model, &state.task).clone();
    let minfo = engine.manifest.model(&state.model).clone();
    let dense = dense_cost(env, &minfo, prune_cfg.target_mode);
    let mut trainer = Trainer::new(engine, tinfo.n_params, teacher);
    let mut out = Vec::new();
    for &target in targets {
        let report = prune_to_target(engine, &mut state, data, env, dense, target, prune_cfg)?;
        trainer.reset_moments();
        let final_loss = trainer.train(&mut state, data, train_cfg)?;
        out.push(StageResult { report, state: state.clone(), final_train_loss: final_loss });
    }
    Ok(out)
}

/// Write the family manifest + per-member checkpoints for a finished
/// gradual run (paper App. F: one run, a whole certified family). The
/// dense teacher becomes the `"dense"` member; each SPDY stage becomes
/// a `"<target>x"` member carrying its certified profile/speedup —
/// certified against exactly the `env` the run targeted, which the
/// manifest embeds in full so `serve-family` admission prices with
/// the same value instead of re-measuring. The env's shape-bucket
/// ladder ([`InferenceEnv::bucket_ladder`]) is recorded alongside, so
/// serving tools shape batches and specialized executables at exactly
/// the buckets certification priced (DESIGN.md §9).
pub fn emit_family(
    env: &InferenceEnv,
    dense: &ModelState,
    stages: &[StageResult],
    dir: &Path,
) -> Result<FamilyManifest> {
    std::fs::create_dir_all(dir)?;
    let mut fam = FamilyManifest::new(&dense.model, &dense.task, env.regime().name());
    fam.env = Some(env.clone());
    fam.buckets = env.bucket_ladder();
    let dense_profile = dense.masks.summary();
    dense.save(&dir.join("dense.zlm"))?;
    fam.push(FamilyMember {
        tag: "dense".into(),
        ckpt: "dense.zlm".into(),
        target: 1.0,
        est_speedup: env.speedup(&dense_profile),
        choices: Some(CompressionProfile::from_layer_profile(&dense_profile)),
        profile: dense_profile,
        // per-layer SPDY losses are scored relative to dense, so the
        // dense member anchors the adapt frontier at zero
        calib_loss: Some(0.0),
    });
    for s in stages {
        let tag = format!("{:.1}x", s.report.target);
        let ckpt = format!("{tag}.zlm");
        s.state.save(&dir.join(&ckpt))?;
        fam.push(FamilyMember {
            tag,
            ckpt,
            target: s.report.target,
            est_speedup: s.report.est_speedup,
            profile: s.report.layer_profile.clone(),
            choices: Some(s.report.choices.clone()),
            calib_loss: Some(s.report.calib_loss),
        });
    }
    let path = dir.join("family.json");
    fam.save(&path)?;
    println!("[family] wrote {} ({} members)", path.display(), fam.members.len());
    Ok(fam)
}
