//! Traffic-adaptive retargeting: close the loop from serving back to
//! pruning (DESIGN.md §12).
//!
//! A family is certified against ONE [`InferenceEnv`] — the anchor
//! batch shape, seq sweep, and absolute block times admission prices
//! with. The moment real traffic drifts (seq-length mix, batch regime,
//! device slowdowns), that certification goes stale: realized latency
//! and the certified estimate diverge, and the speedup ladder solves
//! for a workload nobody is sending anymore. This module turns the
//! realized [`BucketSample`] stream every serving surface already
//! records into pruning decisions:
//!
//! * [`detect_drift`] — pure statistics over recorded samples: a
//!   request-mass-weighted latency-ratio test (realized / certified),
//!   a traffic-mass shape test against the certifying anchor, and an
//!   overrun rate. No wall clock, no threads: same samples, same
//!   report, bit for bit.
//! * [`fit_env`] — constructs a new env from the observed
//!   distribution: anchor re-pointed at the traffic-mass mean shape,
//!   seq sweep re-anchored onto the observed seq support, and the
//!   whole table skewed by the mean realized/certified ratio (via
//!   [`InferenceEnv::with_device_skew`]), so the fitted env certifies
//!   at what the device actually delivered.
//! * [`frontier_points`] / [`propose_targets`] — fit the
//!   loss-vs-certified-speedup frontier from emitted
//!   [`FamilyManifest`]s (the *Compression Laws* framing) and propose
//!   the next target ladder: the knee of the frontier plus
//!   equal-loss-spaced points, deterministic.
//! * [`AdaptController`] — wires the three into
//!   [`CompressionSession::retarget`]: one capture, a living family
//!   whose members track the workload. Zero Hessian recomputation —
//!   capture-side artifacts are env-free, only the SPDY solve re-runs.
//!
//! Everything decision-making here is a pure function over recorded
//! samples, in the same engine-free, property-testable style as
//! `coordinator::family::route` and `coordinator::fleet::admit`.

use anyhow::{anyhow, Result};

use crate::coordinator::family::BucketSample;
use crate::env::InferenceEnv;
use crate::models::family::FamilyManifest;
use crate::session::CompressionSession;
use crate::util::json::Json;

// ------------------------------------------------------------ drift

/// Thresholds for [`detect_drift`]. A report flags `drifted` only when
/// the sample stream carries at least `min_requests` requests AND one
/// of the two statistics exceeds its tolerance.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftCfg {
    /// tolerated request-weighted mean |realized/certified − 1|
    pub latency_ratio_tol: f64,
    /// tolerated traffic-mass-weighted relative shape deviation from
    /// the certifying anchor
    pub mass_shift_tol: f64,
    /// minimum requests before a stream counts as evidence
    pub min_requests: usize,
}

impl Default for DriftCfg {
    fn default() -> DriftCfg {
        DriftCfg { latency_ratio_tol: 0.1, mass_shift_tol: 0.25, min_requests: 16 }
    }
}

/// Per-(batch, seq) drift row: where the traffic mass sits and how the
/// realized latency compares to the certified estimate there.
#[derive(Clone, Debug, PartialEq)]
pub struct BucketDrift {
    /// executed batch dimension
    pub batch: usize,
    /// executed padded seq
    pub seq: usize,
    /// requests served at this shape
    pub requests: usize,
    /// fraction of all requests served at this shape (traffic mass)
    pub share: f64,
    /// request-weighted mean realized/certified latency ratio
    pub latency_ratio: f64,
}

/// Outcome of [`detect_drift`]: the three drift statistics, the anchor
/// they were measured against, and the per-shape mass breakdown.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftReport {
    /// total requests in the sample stream
    pub requests: usize,
    /// certifying anchor `(batch, seq)` the shape test compared against
    pub anchor: (usize, usize),
    /// request-weighted mean |realized/certified − 1|
    pub latency_drift: f64,
    /// traffic-mass-weighted mean relative `(batch, seq)` deviation
    /// from the anchor (0 = every batch executed at the anchor shape)
    pub mass_shift: f64,
    /// fraction of requests whose batch ran over its certified estimate
    pub overrun_rate: f64,
    /// per-(batch, seq) mass + latency-ratio rows, shape ascending
    pub per_bucket: Vec<BucketDrift>,
    /// whether the thresholds in the driving [`DriftCfg`] were crossed
    pub drifted: bool,
}

impl DriftReport {
    /// Serialize (stable schema; floats unrounded).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("anchor_batch", Json::Num(self.anchor.0 as f64)),
            ("anchor_seq", Json::Num(self.anchor.1 as f64)),
            ("latency_drift", Json::Num(self.latency_drift)),
            ("mass_shift", Json::Num(self.mass_shift)),
            ("overrun_rate", Json::Num(self.overrun_rate)),
            (
                "per_bucket",
                Json::Arr(
                    self.per_bucket
                        .iter()
                        .map(|b| {
                            Json::obj(vec![
                                ("batch", Json::Num(b.batch as f64)),
                                ("seq", Json::Num(b.seq as f64)),
                                ("requests", Json::Num(b.requests as f64)),
                                ("share", Json::Num(b.share)),
                                ("latency_ratio", Json::Num(b.latency_ratio)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("drifted", Json::Bool(self.drifted)),
        ])
    }

    /// Parse the [`DriftReport::to_json`] form.
    pub fn from_json(j: &Json) -> Result<DriftReport> {
        let num = |k: &str| -> Result<f64> {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("drift report: no `{k}`"))
        };
        let per_bucket = j
            .get("per_bucket")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|b| {
                Some(BucketDrift {
                    batch: b.get("batch")?.as_usize()?,
                    seq: b.get("seq")?.as_usize()?,
                    requests: b.get("requests")?.as_usize()?,
                    share: b.get("share")?.as_f64()?,
                    latency_ratio: b.get("latency_ratio")?.as_f64()?,
                })
            })
            .collect();
        Ok(DriftReport {
            requests: num("requests")? as usize,
            anchor: (num("anchor_batch")? as usize, num("anchor_seq")? as usize),
            latency_drift: num("latency_drift")?,
            mass_shift: num("mass_shift")?,
            overrun_rate: num("overrun_rate")?,
            per_bucket,
            drifted: j.get("drifted").and_then(Json::as_bool).unwrap_or(false),
        })
    }
}

/// Realized/certified latency ratio of one sample (1.0 when the sample
/// carries no usable certified estimate).
fn sample_ratio(s: &BucketSample) -> f64 {
    if s.certified > 0.0 {
        s.exec.as_secs_f64() / s.certified
    } else {
        1.0
    }
}

/// Pure drift detector: compare the realized `(batch, seq, latency)`
/// distribution in `samples` against the certifying `env`.
///
/// Three statistics, all request-mass weighted so busy shapes dominate
/// idle ones and the result is invariant to how batches were chunked:
///
/// * `latency_drift` — mean |realized/certified − 1| per batch;
/// * `mass_shift` — mean relative `(batch, seq)` deviation from the
///   env's anchor shape (each axis normalized by the anchor, averaged);
/// * `overrun_rate` — fraction of requests whose batch exceeded its
///   certified estimate.
///
/// No wall-clock dependence: the function of `(samples, env, cfg)` is
/// total and deterministic, so it proptests like `route()` does.
pub fn detect_drift(samples: &[BucketSample], env: &InferenceEnv, cfg: &DriftCfg) -> DriftReport {
    let anchor = env.batch_shape();
    let total: usize = samples.iter().map(|s| s.requests).sum();
    if total == 0 {
        return DriftReport {
            requests: 0,
            anchor,
            latency_drift: 0.0,
            mass_shift: 0.0,
            overrun_rate: 0.0,
            per_bucket: Vec::new(),
            drifted: false,
        };
    }
    let (ab, aseq) = anchor;
    let mut latency_drift = 0.0;
    let mut mass_shift = 0.0;
    let mut overrun_rate = 0.0;
    // (batch, seq) → (requests, Σ requests·ratio)
    let mut by: std::collections::BTreeMap<(usize, usize), (usize, f64)> =
        std::collections::BTreeMap::new();
    for s in samples {
        let w = s.requests as f64 / total as f64;
        let ratio = sample_ratio(s);
        latency_drift += w * (ratio - 1.0).abs();
        if s.exec.as_secs_f64() > s.certified {
            overrun_rate += w;
        }
        let ds = if aseq > 0 { (s.seq as f64 - aseq as f64).abs() / aseq as f64 } else { 0.0 };
        let db = if ab > 0 { (s.batch as f64 - ab as f64).abs() / ab as f64 } else { 0.0 };
        mass_shift += w * 0.5 * (ds + db);
        let e = by.entry((s.batch, s.seq)).or_insert((0, 0.0));
        e.0 += s.requests;
        e.1 += s.requests as f64 * ratio;
    }
    let per_bucket = by
        .into_iter()
        .map(|((batch, seq), (requests, ratio_sum))| BucketDrift {
            batch,
            seq,
            requests,
            share: requests as f64 / total as f64,
            latency_ratio: ratio_sum / requests as f64,
        })
        .collect();
    let drifted = total >= cfg.min_requests
        && (latency_drift > cfg.latency_ratio_tol || mass_shift > cfg.mass_shift_tol);
    DriftReport { requests: total, anchor, latency_drift, mass_shift, overrun_rate, per_bucket, drifted }
}

// ------------------------------------------------------------ fitting

/// Fit a new [`InferenceEnv`] to the observed traffic distribution.
///
/// The fitted env is `base` re-anchored and re-priced:
///
/// * anchor `(batch, seq)` moves to the request-mass-weighted mean
///   observed shape (rounded);
/// * the seq sweep is rebuilt on the OBSERVED seq support, each row's
///   scale re-normalized so the new anchor seq prices at 1.0 (reusing
///   the base sweep's interpolation — the `regime_sweep` /
///   `analytic_seq_sweep` machinery the base env was built from);
/// * every absolute time is skewed by the mean realized/certified
///   ratio times the relative cost of the new anchor under the base
///   env, so that at the new anchor shape the fitted env certifies
///   exactly what serving realized.
///
/// Pure in `(samples, base)` — bit-deterministic, engine-free.
pub fn fit_env(samples: &[BucketSample], base: &InferenceEnv) -> Result<InferenceEnv> {
    let total: usize = samples.iter().map(|s| s.requests).sum();
    if total == 0 {
        return Err(anyhow!("fit_env needs at least one recorded request"));
    }
    let mut mean_b = 0.0;
    let mut mean_s = 0.0;
    let mut ratio = 0.0;
    for s in samples {
        let w = s.requests as f64 / total as f64;
        mean_b += w * s.batch as f64;
        mean_s += w * s.seq as f64;
        ratio += w * sample_ratio(s);
    }
    let b_star = (mean_b.round() as usize).max(1);
    let s_star = (mean_s.round() as usize).max(1);
    let (b0, _) = base.batch_shape();
    let batch_factor = if b0 > 0 { b_star as f64 / b0 as f64 } else { 1.0 };
    let anchor_scale = base.seq_scale(s_star);
    let skew = ratio * batch_factor * anchor_scale;
    let mut seqs: Vec<usize> = samples.iter().map(|s| s.seq).filter(|&s| s > 0).collect();
    seqs.sort_unstable();
    seqs.dedup();
    let sweep: Vec<(usize, f64)> =
        seqs.into_iter().map(|s| (s, base.seq_scale(s) / anchor_scale)).collect();
    Ok(base
        .with_device_skew(skew)
        .with_batch_shape(b_star, s_star)
        .with_seq_sweep(sweep))
}

// ----------------------------------------------------------- frontier

/// One point on the loss-vs-certified-speedup frontier.
#[derive(Clone, Debug, PartialEq)]
pub struct FrontierPoint {
    /// certified speedup (x axis)
    pub speedup: f64,
    /// calibration loss, or the `1 − 1/speedup` proxy for members that
    /// recorded none (y axis; lower is better)
    pub loss: f64,
    /// member tag the point came from (diagnostics)
    pub tag: String,
}

/// Deterministic loss proxy for family members emitted before
/// calibration losses were recorded: monotone in speedup, 0 at dense.
pub fn loss_proxy(est_speedup: f64) -> f64 {
    if est_speedup > 0.0 {
        1.0 - 1.0 / est_speedup
    } else {
        0.0
    }
}

/// Collect every member of every manifest as a candidate point and
/// keep the Pareto frontier: no kept point is dominated by another
/// with ≥ speedup and ≤ loss. Result is ascending in speedup AND in
/// loss — the usable accuracy-vs-speedup trade-off curve.
pub fn frontier_points(manifests: &[FamilyManifest]) -> Vec<FrontierPoint> {
    let mut pts: Vec<FrontierPoint> = Vec::new();
    for fam in manifests {
        for m in &fam.members {
            let loss = match m.calib_loss {
                Some(l) if l.is_finite() => l,
                _ => loss_proxy(m.est_speedup),
            };
            if m.est_speedup.is_finite() && loss.is_finite() {
                pts.push(FrontierPoint { speedup: m.est_speedup, loss, tag: m.tag.clone() });
            }
        }
    }
    pts.sort_by(|a, b| {
        a.speedup.total_cmp(&b.speedup).then(a.loss.total_cmp(&b.loss)).then(a.tag.cmp(&b.tag))
    });
    // sweep from the fastest point down: keep strictly-improving losses
    let mut kept: Vec<FrontierPoint> = Vec::new();
    let mut best = f64::INFINITY;
    for p in pts.into_iter().rev() {
        if p.loss < best {
            best = p.loss;
            kept.push(p);
        }
    }
    kept.reverse();
    kept
}

/// Knee of the frontier: the point farthest from the chord between the
/// endpoints, axes normalized to [0, 1] so the pick is scale-free.
/// Deterministic (first strict maximum wins); `None` below 3 points.
pub fn knee_point(frontier: &[FrontierPoint]) -> Option<f64> {
    if frontier.len() < 3 {
        return None;
    }
    let (a, b) = (&frontier[0], &frontier[frontier.len() - 1]);
    let dx = b.speedup - a.speedup;
    let dy = b.loss - a.loss;
    if dx <= 0.0 {
        return None;
    }
    let sy = if dy != 0.0 { dy } else { 1.0 };
    let mut best = 0.0;
    let mut at: Option<f64> = None;
    for p in &frontier[1..frontier.len() - 1] {
        let px = (p.speedup - a.speedup) / dx;
        let py = (p.loss - a.loss) / sy;
        // |cross product| of (1, dy/sy) × (px, py) in normalized axes
        let d = (px * (dy / sy) - py).abs();
        if d > best {
            best = d;
            at = Some(p.speedup);
        }
    }
    at.or(Some(frontier[frontier.len() / 2].speedup))
}

/// Propose the next `n` speedup targets from the frontier: the knee
/// point plus `n` equal-loss-spaced picks (for each evenly spaced loss
/// level, the fastest frontier point whose loss does not exceed it),
/// deduplicated and ascending. Empty frontier → empty proposal.
pub fn propose_targets(frontier: &[FrontierPoint], n: usize) -> Vec<f64> {
    if frontier.is_empty() || n == 0 {
        return Vec::new();
    }
    let y0 = frontier[0].loss;
    let y1 = frontier[frontier.len() - 1].loss;
    let mut out: Vec<f64> = Vec::new();
    if let Some(k) = knee_point(frontier) {
        out.push(k);
    }
    for k in 1..=n {
        let want = y0 + (y1 - y0) * k as f64 / n as f64;
        let mut pick = frontier[0].speedup;
        for p in frontier {
            if p.loss <= want + 1e-12 {
                pick = p.speedup;
            }
        }
        out.push(pick);
    }
    out.sort_by(|a, b| a.total_cmp(b));
    out.dedup();
    out
}

// --------------------------------------------------------- controller

/// The full adaptation decision: what drifted, what env fits the
/// observed traffic, and which targets the frontier recommends.
#[derive(Clone, Debug, PartialEq)]
pub struct AdaptPlan {
    /// the drift report that triggered (or held) the plan
    pub drift: DriftReport,
    /// env fitted to the observed distribution (present iff drifted)
    pub fitted: Option<InferenceEnv>,
    /// recommended speedup targets (knee + equal-loss-spaced)
    pub targets: Vec<f64>,
    /// the frontier knee, when one exists
    pub knee: Option<f64>,
}

impl AdaptPlan {
    /// What the controller will do with this plan.
    pub fn action(&self) -> &'static str {
        if self.drift.drifted && self.fitted.is_some() {
            "retarget"
        } else {
            "hold"
        }
    }

    /// Serialize (the fitted env embeds in full, so a plan file is
    /// self-contained input for `prune-gradual --retarget`).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("drift", self.drift.to_json())];
        if let Some(env) = &self.fitted {
            pairs.push(("fitted_env", env.to_json()));
        }
        if let Some(k) = self.knee {
            pairs.push(("knee", Json::Num(k)));
        }
        pairs.push(("targets", Json::arr_f64(&self.targets)));
        pairs.push(("action", Json::Str(self.action().to_string())));
        Json::obj(pairs)
    }

    /// Parse the [`AdaptPlan::to_json`] form (the `action` key is
    /// derived state and ignored on read).
    pub fn from_json(j: &Json) -> Result<AdaptPlan> {
        let drift = DriftReport::from_json(
            j.get("drift").ok_or_else(|| anyhow!("adapt plan: no `drift`"))?,
        )?;
        let fitted = j.get("fitted_env").map(InferenceEnv::from_json).transpose()?;
        let targets = j
            .get("targets")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(Json::as_f64)
            .collect();
        Ok(AdaptPlan { drift, fitted, targets, knee: j.get("knee").and_then(Json::as_f64) })
    }
}

/// Policy knobs + the one-call entry points gluing detector, fitter,
/// and frontier to a [`CompressionSession`].
#[derive(Clone, Debug)]
pub struct AdaptController {
    /// drift thresholds
    pub cfg: DriftCfg,
    /// how many equal-loss-spaced targets to propose
    pub n_targets: usize,
}

impl Default for AdaptController {
    fn default() -> AdaptController {
        AdaptController { cfg: DriftCfg::default(), n_targets: 3 }
    }
}

impl AdaptController {
    /// Build the full [`AdaptPlan`] for one sample stream: detect
    /// drift against `env`, fit a replacement env when drifted, and
    /// propose targets from the manifests' frontier. Pure.
    pub fn plan(
        &self,
        samples: &[BucketSample],
        env: &InferenceEnv,
        manifests: &[FamilyManifest],
    ) -> Result<AdaptPlan> {
        let drift = detect_drift(samples, env, &self.cfg);
        let fitted = if drift.drifted { Some(fit_env(samples, env)?) } else { None };
        let frontier = frontier_points(manifests);
        let targets = propose_targets(&frontier, self.n_targets);
        let knee = knee_point(&frontier);
        Ok(AdaptPlan { drift, fitted, targets, knee })
    }

    /// Apply a plan to a live session: when it says retarget, swap the
    /// session onto the fitted env ([`CompressionSession::retarget`] —
    /// zero Hessian recomputation; the next solve re-prices the same
    /// checkpointed databases). Returns whether a retarget happened.
    pub fn apply(&self, plan: &AdaptPlan, sess: &mut CompressionSession) -> Result<bool> {
        match (&plan.fitted, plan.drift.drifted) {
            (Some(env), true) => {
                sess.retarget(env.clone())?;
                Ok(true)
            }
            _ => Ok(false),
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::latency::LatencyTable;
    use crate::models::family::FamilyMember;
    use std::time::Duration;

    fn env() -> InferenceEnv {
        InferenceEnv::measured(LatencyTable {
            model: "m".into(),
            device: "sim".into(),
            regime: "throughput".into(),
            attn: vec![0.0, 1.0e-3, 1.8e-3, 2.5e-3, 3.1e-3],
            mlp: vec![(512, 8e-3), (256, 4.2e-3), (64, 1.5e-3), (0, 0.0)],
            overhead: 1e-3,
        })
        .unwrap()
        .with_batch_shape(8, 64)
        .with_seq_sweep(vec![(16, 0.4), (32, 0.7), (64, 1.0)])
    }

    fn sample(batch: usize, seq: usize, ratio: f64, requests: usize) -> BucketSample {
        let certified = 4e-3;
        BucketSample {
            member: "2x".into(),
            batch,
            seq,
            specialized: true,
            exec: Duration::from_secs_f64(certified * ratio),
            requests,
            certified,
        }
    }

    #[test]
    fn anchor_traffic_at_certified_latency_never_drifts() {
        let samples: Vec<BucketSample> = (0..10).map(|_| sample(8, 64, 1.0, 8)).collect();
        let r = detect_drift(&samples, &env(), &DriftCfg::default());
        assert_eq!(r.requests, 80);
        assert_eq!(r.latency_drift, 0.0);
        assert_eq!(r.mass_shift, 0.0);
        assert_eq!(r.overrun_rate, 0.0);
        assert!(!r.drifted);
        assert_eq!(r.per_bucket.len(), 1);
        assert_eq!(r.per_bucket[0].share, 1.0);
    }

    #[test]
    fn empty_stream_and_thin_evidence_hold() {
        let r = detect_drift(&[], &env(), &DriftCfg::default());
        assert!(!r.drifted);
        assert_eq!(r.requests, 0);
        // massive drift but below min_requests → still hold
        let samples = vec![sample(8, 16, 3.0, 4)];
        let r = detect_drift(&samples, &env(), &DriftCfg::default());
        assert!(r.latency_drift > 1.0);
        assert!(!r.drifted, "4 requests are not evidence at min_requests=16");
    }

    #[test]
    fn latency_and_mass_drift_flag_and_scale_monotonically() {
        let e = env();
        let cfg = DriftCfg::default();
        let mut last = 0.0;
        for shift in [1.05, 1.2, 1.5, 2.0] {
            let samples: Vec<BucketSample> = (0..8).map(|_| sample(8, 64, shift, 8)).collect();
            let r = detect_drift(&samples, &e, &cfg);
            assert!(r.latency_drift > last, "monotone in injected shift");
            last = r.latency_drift;
        }
        assert!(last > cfg.latency_ratio_tol);
        // seq mass moving off the anchor flags the mass test
        let short: Vec<BucketSample> = (0..8).map(|_| sample(8, 16, 1.0, 8)).collect();
        let r = detect_drift(&short, &e, &cfg);
        assert!((r.mass_shift - 0.375).abs() < 1e-12, "{}", r.mass_shift);
        assert!(r.drifted);
    }

    #[test]
    fn drift_report_json_round_trips() {
        let samples: Vec<BucketSample> =
            (0..6).map(|i| sample(8, if i % 2 == 0 { 16 } else { 64 }, 1.3, 5)).collect();
        let r = detect_drift(&samples, &env(), &DriftCfg::default());
        let back = DriftReport::from_json(&r.to_json()).unwrap();
        assert_eq!(r, back);
        let back2 = DriftReport::from_json(
            &crate::util::json::Json::parse(&r.to_json().to_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(r, back2);
    }

    #[test]
    fn fitted_env_tracks_the_observed_distribution() {
        let e = env();
        // all traffic at (8, 16), running 1.5x over certified
        let samples: Vec<BucketSample> = (0..8).map(|_| sample(8, 16, 1.5, 8)).collect();
        let f = fit_env(&samples, &e).unwrap();
        assert_eq!(f.batch_shape(), (8, 16));
        // observed support only, re-anchored to scale 1.0
        assert_eq!(f.seq_sweep(), &[(16, 1.0)]);
        // at the new anchor the fitted env certifies what was realized:
        // base price at (8,16) is model_time * 0.4; realized 1.5x that
        let profile = vec![(2usize, 256usize); 2];
        let want = e.batch_time(&profile, 8, 16) * 1.5;
        let got = f.batch_time(&profile, 8, 16);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        // deterministic
        assert_eq!(f, fit_env(&samples, &e).unwrap());
        assert!(fit_env(&[], &e).is_err());
    }

    fn member(tag: &str, est: f64, loss: Option<f64>) -> FamilyMember {
        FamilyMember {
            tag: tag.into(),
            ckpt: format!("{tag}.zlm"),
            target: est,
            est_speedup: est,
            profile: vec![(2, 8)],
            choices: None,
            calib_loss: loss,
        }
    }

    fn manifest(members: Vec<FamilyMember>) -> FamilyManifest {
        let mut f = FamilyManifest::new("m", "t", "throughput");
        for m in members {
            f.push(m);
        }
        f
    }

    #[test]
    fn frontier_is_pareto_and_deterministic() {
        let fam = manifest(vec![
            member("dense", 1.0, Some(0.0)),
            member("2x", 2.0, Some(0.1)),
            member("2x-bad", 1.9, Some(0.5)), // dominated by 2x
            member("3x", 3.0, Some(0.3)),
            member("4x", 4.1, None), // proxy loss 1 − 1/4.1 ≈ 0.756
        ]);
        let f = frontier_points(&[fam.clone()]);
        let tags: Vec<&str> = f.iter().map(|p| p.tag.as_str()).collect();
        assert_eq!(tags, vec!["dense", "2x", "3x", "4x"]);
        for w in f.windows(2) {
            assert!(w[0].speedup < w[1].speedup && w[0].loss <= w[1].loss);
        }
        assert_eq!(f, frontier_points(&[fam]));
    }

    #[test]
    fn targets_span_the_frontier_and_include_the_knee() {
        let fam = manifest(vec![
            member("dense", 1.0, Some(0.0)),
            member("2x", 2.0, Some(0.02)),
            member("3x", 3.0, Some(0.05)),
            member("6x", 6.0, Some(0.60)),
        ]);
        let f = frontier_points(&[fam]);
        let knee = knee_point(&f).unwrap();
        // 3x is the sharp corner of this curve
        assert_eq!(knee, 3.0);
        let t = propose_targets(&f, 3);
        assert!(t.contains(&knee));
        assert!(t.windows(2).all(|w| w[0] < w[1]), "{t:?}");
        assert_eq!(*t.last().unwrap(), 6.0, "the fastest point is always proposed");
        assert!(propose_targets(&[], 3).is_empty());
    }

    #[test]
    fn plan_round_trips_and_holds_without_drift() {
        let e = env();
        let ctl = AdaptController::default();
        let fams = [manifest(vec![
            member("dense", 1.0, Some(0.0)),
            member("2x", 2.0, Some(0.1)),
            member("3x", 3.0, Some(0.4)),
        ])];
        // calm traffic → hold, no fitted env
        let calm: Vec<BucketSample> = (0..8).map(|_| sample(8, 64, 1.0, 8)).collect();
        let plan = ctl.plan(&calm, &e, &fams).unwrap();
        assert_eq!(plan.action(), "hold");
        assert!(plan.fitted.is_none());
        assert!(!plan.targets.is_empty());
        // drifted traffic → retarget with an embedded fitted env
        let hot: Vec<BucketSample> = (0..8).map(|_| sample(8, 16, 1.6, 8)).collect();
        let plan = ctl.plan(&hot, &e, &fams).unwrap();
        assert_eq!(plan.action(), "retarget");
        let back = AdaptPlan::from_json(
            &crate::util::json::Json::parse(&plan.to_json().to_pretty()).unwrap(),
        )
        .unwrap();
        assert_eq!(plan, back);
        assert_eq!(back.fitted.as_ref().unwrap().batch_shape(), (8, 16));
    }
}
