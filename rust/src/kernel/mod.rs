//! Runtime-dispatched SIMD kernel layer (DESIGN.md §14).
//!
//! The five hot kernels — OBS `scores`/`update`/`multi_update`
//! (ziplm/), the SPD inverse (tensor/linalg) and the tiled GEMM
//! (tensor/) — route their inner loops through [`Dispatch`]: a small
//! set of slice primitives with explicitly vectorized x86-64
//! implementations (AVX2 and SSE2, picked once at runtime via CPUID)
//! and the original scalar loops as the mandatory fallback. The scalar
//! level is also the only one compiled on non-x86 targets or under
//! `--features no-simd`, which CI builds and tests so the fallback can
//! never rot.
//!
//! **Determinism contract.** Every primitive is restricted to
//! element-wise lane arithmetic in the scalar code's exact evaluation
//! order: packed multiply then packed add/sub — never FMA, which skips
//! the intermediate rounding and changes bits — sign flips via XOR
//! (bitwise, like Rust `-x`), and per-lane f32→f64 widening for the
//! column-sum-of-squares accumulators. Cross-lane reductions are
//! banned. The SPD inverse vectorizes ACROSS columns instead of within
//! its dot products ([`Dispatch::spd_solve_lanes`]: each SIMD lane
//! owns one column's triangular solves, so every lane reproduces the
//! scalar per-column accumulation order term by term). Consequently
//! the dispatch level changes throughput, never bits:
//! `tests/kernel_equiv.rs` asserts exact `to_bits` equality between
//! every available level and scalar on every primitive (including
//! remainder-lane lengths), and the certified `repro --kick-tires`
//! goldens are insensitive to the level by construction.
//!
//! [`AliveSet`] carries the compacted alive-column bookkeeping that
//! lets `multi_update`'s per-step O(d²) passes skip removed columns
//! instead of multiplying by their exact zeros (DESIGN.md §14).

#[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
mod x86;

use std::cell::Cell;
use std::sync::OnceLock;

/// One vector width the dispatcher can run a primitive at.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// The original scalar loops — the mandatory fallback.
    Scalar,
    /// 128-bit SSE2 (4 f32 lanes) — baseline on every x86-64 CPU.
    Sse2,
    /// 256-bit AVX2 (8 f32 lanes), detected at runtime.
    Avx2,
}

impl Level {
    /// f32 lanes per vector op at this level.
    pub fn lanes(self) -> usize {
        match self {
            Level::Scalar => 1,
            Level::Sse2 => 4,
            Level::Avx2 => 8,
        }
    }

    /// Best level this machine supports, probed once and cached.
    pub fn detect() -> Level {
        static DETECTED: OnceLock<Level> = OnceLock::new();
        *DETECTED.get_or_init(probe)
    }

    /// Every level available on this machine, scalar first. Tests
    /// iterate this to force each level through [`with_level`].
    pub fn available() -> Vec<Level> {
        match Level::detect() {
            Level::Scalar => vec![Level::Scalar],
            Level::Sse2 => vec![Level::Scalar, Level::Sse2],
            Level::Avx2 => vec![Level::Scalar, Level::Sse2, Level::Avx2],
        }
    }

    fn is_available(self) -> bool {
        Level::available().contains(&self)
    }
}

fn probe() -> Level {
    #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            Level::Avx2
        } else {
            // SSE2 is part of the x86-64 baseline: always present.
            Level::Sse2
        }
    }
    #[cfg(not(all(target_arch = "x86_64", not(feature = "no-simd"))))]
    {
        Level::Scalar
    }
}

thread_local! {
    /// Per-thread level override installed by [`with_level`] (tests).
    static FORCED: Cell<Option<Level>> = const { Cell::new(None) };
}

/// Run `f` with the dispatch level pinned to `level` on this thread,
/// restoring the previous override afterwards (also on panic). Panics
/// if the machine does not support `level` — iterate
/// [`Level::available`] instead of hardcoding levels.
///
/// Kernels capture their [`Dispatch`] once per call *before* fanning
/// out to worker threads, so a forced level propagates into threaded
/// sweeps even though the override itself is thread-local.
pub fn with_level<T>(level: Level, f: impl FnOnce() -> T) -> T {
    assert!(level.is_available(), "kernel level {level:?} not available on this machine");
    struct Restore(Option<Level>);
    impl Drop for Restore {
        fn drop(&mut self) {
            FORCED.with(|c| c.set(self.0));
        }
    }
    let prev = FORCED.with(|c| c.get());
    let _guard = Restore(prev);
    FORCED.with(|c| c.set(Some(level)));
    f()
}

/// The dispatch handle: resolves the active level once, then routes
/// each primitive to that level's implementation. `Copy`, so kernels
/// grab one per call and pass it into their inner loops (and into
/// scoped worker threads) without re-probing.
#[derive(Clone, Copy, Debug)]
pub struct Dispatch {
    level: Level,
}

impl Dispatch {
    /// The active level: a [`with_level`] override if installed on
    /// this thread, the detected machine level otherwise.
    pub fn get() -> Dispatch {
        let level = FORCED.with(|c| c.get()).unwrap_or_else(Level::detect);
        Dispatch { level }
    }

    /// A handle pinned to an explicit level (test support). Panics if
    /// the machine does not support `level`.
    pub fn at(level: Level) -> Dispatch {
        assert!(level.is_available(), "kernel level {level:?} not available on this machine");
        Dispatch { level }
    }

    pub fn level(self) -> Level {
        self.level
    }

    /// f32 lanes per vector op; callers that block work by lane width
    /// (the SPD column-block solves) size their groups with this.
    pub fn lanes(self) -> usize {
        self.level.lanes()
    }

    /// `dst[i] += a * x[i]` — the P-build / GEMM-tail axpy.
    pub fn axpy(self, dst: &mut [f32], a: f32, x: &[f32]) {
        match self.level {
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Sse2 => unsafe { x86::axpy_sse2(dst, a, x) },
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Avx2 => unsafe { x86::axpy_avx2(dst, a, x) },
            _ => scalar::axpy(dst, a, x),
        }
    }

    /// `dst[i] -= a * x[i]` — the OBS downdate axpy.
    pub fn axpy_minus(self, dst: &mut [f32], a: f32, x: &[f32]) {
        match self.level {
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Sse2 => unsafe { x86::axpy_minus_sse2(dst, a, x) },
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Avx2 => unsafe { x86::axpy_minus_avx2(dst, a, x) },
            _ => scalar::axpy_minus(dst, a, x),
        }
    }

    /// Fused `multi_update` W pass: `dst[i] -= a * x[i]` while
    /// maintaining `colsq[i] += dst[i]² − old²` in f64 (the
    /// incremental column-sum-of-squares from PR 4, one pass).
    pub fn axpy_minus_colsq(self, dst: &mut [f32], a: f32, x: &[f32], colsq: &mut [f64]) {
        match self.level {
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Sse2 => unsafe { x86::axpy_minus_colsq_sse2(dst, a, x, colsq) },
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Avx2 => unsafe { x86::axpy_minus_colsq_avx2(dst, a, x, colsq) },
            _ => scalar::axpy_minus_colsq(dst, a, x, colsq),
        }
    }

    /// `colsq[i] += row[i]²` in f64 — the g=1 scores column pass.
    pub fn colsq_accum(self, colsq: &mut [f64], row: &[f32]) {
        match self.level {
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Sse2 => unsafe { x86::colsq_accum_sse2(colsq, row) },
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Avx2 => unsafe { x86::colsq_accum_avx2(colsq, row) },
            _ => scalar::colsq_accum(colsq, row),
        }
    }

    /// `dst[i] *= s` — the p = Hinv row / Hinv_jj scaling.
    pub fn scale(self, dst: &mut [f32], s: f32) {
        match self.level {
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Sse2 => unsafe { x86::scale_sse2(dst, s) },
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Avx2 => unsafe { x86::scale_avx2(dst, s) },
            _ => scalar::scale(dst, s),
        }
    }

    /// GEMM quad-row inner kernel:
    /// `dst[j] += a[0]·b0[j] + a[1]·b1[j] + a[2]·b2[j] + a[3]·b3[j]`
    /// with the scalar expression's left-to-right addition tree.
    pub fn quad_axpy(
        self,
        dst: &mut [f32],
        a: [f32; 4],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
    ) {
        match self.level {
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Sse2 => unsafe { x86::quad_axpy_sse2(dst, a, b0, b1, b2, b3) },
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Avx2 => unsafe { x86::quad_axpy_avx2(dst, a, b0, b1, b2, b3) },
            _ => scalar::quad_axpy(dst, a, b0, b1, b2, b3),
        }
    }

    /// Column-block triangular solves for the SPD inverse: lane `l`
    /// solves `L y = e_{j0+l}` then `Lᵀ x = y`, all lanes in lockstep.
    ///
    /// `ld`/`ltd` are the row-major Cholesky factor and its transpose,
    /// `y`/`x` are `[n][lanes]` interleaved buffers. Lanes whose
    /// column starts after the current row accumulate exact ±0 terms
    /// until their pivot row, so each lane's arithmetic is the scalar
    /// column solve's, term for term (DESIGN.md §14). Lanes past
    /// `n - j0` (remainder groups) compute harmless garbage that the
    /// caller never scatters. Panics at the `Scalar` level — the
    /// caller keeps the original per-column loop as its fallback.
    pub fn spd_solve_lanes(
        self,
        ld: &[f32],
        ltd: &[f32],
        n: usize,
        j0: usize,
        y: &mut [f32],
        x: &mut [f32],
    ) {
        debug_assert!(y.len() >= n * self.lanes() && x.len() >= n * self.lanes());
        match self.level {
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Sse2 => unsafe { x86::spd_solve_lanes_sse2(ld, ltd, n, j0, y, x) },
            #[cfg(all(target_arch = "x86_64", not(feature = "no-simd")))]
            Level::Avx2 => unsafe { x86::spd_solve_lanes_avx2(ld, ltd, n, j0, y, x) },
            _ => unreachable!("spd_solve_lanes has no scalar level; gate on lanes() > 1"),
        }
    }
}

/// Scalar reference implementations — the mandatory fallback level and
/// the bit-equality oracle for every vector path.
pub(crate) mod scalar {
    pub fn axpy(dst: &mut [f32], a: f32, x: &[f32]) {
        for (d, v) in dst.iter_mut().zip(x) {
            *d += a * v;
        }
    }

    pub fn axpy_minus(dst: &mut [f32], a: f32, x: &[f32]) {
        for (d, v) in dst.iter_mut().zip(x) {
            *d -= a * v;
        }
    }

    pub fn axpy_minus_colsq(dst: &mut [f32], a: f32, x: &[f32], colsq: &mut [f64]) {
        for ((d, v), acc) in dst.iter_mut().zip(x).zip(colsq.iter_mut()) {
            let old = *d as f64;
            *d -= a * v;
            *acc += (*d as f64) * (*d as f64) - old * old;
        }
    }

    pub fn colsq_accum(colsq: &mut [f64], row: &[f32]) {
        for (acc, &v) in colsq.iter_mut().zip(row) {
            *acc += (v as f64) * (v as f64);
        }
    }

    pub fn scale(dst: &mut [f32], s: f32) {
        for d in dst.iter_mut() {
            *d *= s;
        }
    }

    pub fn quad_axpy(dst: &mut [f32], a: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) {
        for (j, d) in dst.iter_mut().enumerate() {
            *d += a[0] * b0[j] + a[1] * b1[j] + a[2] * b2[j] + a[3] * b3[j];
        }
    }
}

// ------------------------------------------------------------ alive set

/// Per-step sweeps of `multi_update` go compact (walk the alive index
/// list) below this alive fraction, and stay dense (full-width SIMD
/// rows over exact zeros) above it. Half-width is where the C mirror
/// measured the indexed-access overhead dropping below the skipped
/// work on the deep FFN ladder; both passes are bit-identical (dead
/// columns only ever contribute exact ±0), so the threshold is purely
/// a performance knob.
pub fn use_compact_pass(alive: usize, d_col: usize) -> bool {
    alive * 2 < d_col
}

/// Compacted ascending list of still-alive column indices: the
/// bookkeeping behind `multi_update`'s alive-restricted per-step
/// passes. Invariant (property-tested): after any removal sequence the
/// list equals the ascending set-difference of the initial indices and
/// the removed ones.
#[derive(Clone, Debug)]
pub struct AliveSet {
    idx: Vec<usize>,
}

impl AliveSet {
    /// Alive indices of an activity mask: ascending `j` with
    /// `active[j] > 0`.
    pub fn from_active(active: &[f32]) -> AliveSet {
        AliveSet { idx: (0..active.len()).filter(|&j| active[j] > 0.0).collect() }
    }

    pub fn len(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// The alive indices, ascending.
    pub fn as_slice(&self) -> &[usize] {
        &self.idx
    }

    pub fn contains(&self, j: usize) -> bool {
        self.idx.binary_search(&j).is_ok()
    }

    /// Remove `j`, keeping the list compact and ascending. Returns
    /// whether it was present.
    pub fn remove(&mut self, j: usize) -> bool {
        match self.idx.binary_search(&j) {
            Ok(pos) => {
                self.idx.remove(pos);
                true
            }
            Err(_) => false,
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // test code: unwrap-on-failure is fine
mod tests {
    use super::*;

    #[test]
    fn detect_is_stable_and_listed() {
        let d = Level::detect();
        assert_eq!(d, Level::detect());
        assert!(Level::available().contains(&d));
        assert_eq!(Level::available()[0], Level::Scalar);
    }

    #[test]
    fn forced_level_applies_and_restores() {
        for lvl in Level::available() {
            with_level(lvl, || assert_eq!(Dispatch::get().level(), lvl));
        }
        assert_eq!(Dispatch::get().level(), Level::detect());
    }

    #[test]
    fn no_simd_feature_is_scalar_only() {
        #[cfg(feature = "no-simd")]
        assert_eq!(Level::available(), vec![Level::Scalar]);
    }

    #[test]
    fn alive_set_basic_ops() {
        let act = [1.0f32, 0.0, 0.5, 1.0, 0.0];
        let mut a = AliveSet::from_active(&act);
        assert_eq!(a.as_slice(), &[0, 2, 3]);
        assert!(a.contains(2) && !a.contains(1));
        assert!(a.remove(2));
        assert!(!a.remove(2));
        assert_eq!(a.as_slice(), &[0, 3]);
        assert_eq!(a.len(), 2);
        assert!(!a.is_empty());
    }

    #[test]
    fn compact_policy_threshold() {
        assert!(!use_compact_pass(512, 512));
        assert!(!use_compact_pass(256, 512));
        assert!(use_compact_pass(255, 512));
        assert!(use_compact_pass(0, 512));
    }

    #[test]
    fn scalar_primitives_match_plain_loops() {
        let kd = Dispatch::at(Level::Scalar);
        let mut dst = vec![1.0f32, 2.0, 3.0];
        kd.axpy(&mut dst, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(dst, vec![3.0, 4.0, 5.0]);
        kd.axpy_minus(&mut dst, 1.0, &[1.0, 1.0, 1.0]);
        assert_eq!(dst, vec![2.0, 3.0, 4.0]);
        kd.scale(&mut dst, 0.5);
        assert_eq!(dst, vec![1.0, 1.5, 2.0]);
        let mut colsq = vec![0.0f64; 3];
        kd.colsq_accum(&mut colsq, &dst);
        assert_eq!(colsq, vec![1.0, 2.25, 4.0]);
    }
}
