//! x86-64 SSE2/AVX2 implementations of the [`Dispatch`] primitives.
//!
//! Every function here is bit-identical to its `scalar` twin by
//! construction (DESIGN.md §14): main loops process whole vectors of
//! 4 (SSE2) or 8 (AVX2) f32 lanes with packed multiply-then-add/sub —
//! never FMA, which would skip the intermediate rounding — and a
//! scalar remainder loop that is literally the fallback's body. Sign
//! flips go through XOR with `-0.0` (bitwise, exactly Rust's unary
//! `-`), and the f64 column-sum-of-squares accumulators widen each
//! f32 half-vector with `cvtps_pd`, keeping the per-element
//! `acc + (new² − old²)` evaluation order. No cross-lane reductions
//! anywhere.
//!
//! Safety: all functions are `unsafe` only because of
//! `#[target_feature]`; callers (the [`Dispatch`] match arms) must
//! ensure the feature is available, which `Level::detect`/`Level::at`
//! guarantee. Slice accesses are bounds-derived from `len()` —
//! `spd_solve_lanes_*` additionally `debug_assert!`s its buffer-size
//! contract.
//!
//! [`Dispatch`]: super::Dispatch

#![allow(clippy::missing_safety_doc)] // module-private; contract above

use std::arch::x86_64::*;

// ------------------------------------------------------------- axpy

/// `dst[i] += a * x[i]`, 8 lanes at a time.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_avx2(dst: &mut [f32], a: f32, x: &[f32]) {
    let n = dst.len().min(x.len());
    let dp = dst.as_mut_ptr();
    let xp = x.as_ptr();
    let av = _mm256_set1_ps(a);
    let mut i = 0;
    while i + 8 <= n {
        let d = _mm256_loadu_ps(dp.add(i));
        let v = _mm256_loadu_ps(xp.add(i));
        _mm256_storeu_ps(dp.add(i), _mm256_add_ps(d, _mm256_mul_ps(av, v)));
        i += 8;
    }
    while i < n {
        *dp.add(i) += a * *xp.add(i);
        i += 1;
    }
}

/// `dst[i] += a * x[i]`, 4 lanes at a time.
#[target_feature(enable = "sse2")]
pub unsafe fn axpy_sse2(dst: &mut [f32], a: f32, x: &[f32]) {
    let n = dst.len().min(x.len());
    let dp = dst.as_mut_ptr();
    let xp = x.as_ptr();
    let av = _mm_set1_ps(a);
    let mut i = 0;
    while i + 4 <= n {
        let d = _mm_loadu_ps(dp.add(i));
        let v = _mm_loadu_ps(xp.add(i));
        _mm_storeu_ps(dp.add(i), _mm_add_ps(d, _mm_mul_ps(av, v)));
        i += 4;
    }
    while i < n {
        *dp.add(i) += a * *xp.add(i);
        i += 1;
    }
}

/// `dst[i] -= a * x[i]`, 8 lanes at a time.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_minus_avx2(dst: &mut [f32], a: f32, x: &[f32]) {
    let n = dst.len().min(x.len());
    let dp = dst.as_mut_ptr();
    let xp = x.as_ptr();
    let av = _mm256_set1_ps(a);
    let mut i = 0;
    while i + 8 <= n {
        let d = _mm256_loadu_ps(dp.add(i));
        let v = _mm256_loadu_ps(xp.add(i));
        _mm256_storeu_ps(dp.add(i), _mm256_sub_ps(d, _mm256_mul_ps(av, v)));
        i += 8;
    }
    while i < n {
        *dp.add(i) -= a * *xp.add(i);
        i += 1;
    }
}

/// `dst[i] -= a * x[i]`, 4 lanes at a time.
#[target_feature(enable = "sse2")]
pub unsafe fn axpy_minus_sse2(dst: &mut [f32], a: f32, x: &[f32]) {
    let n = dst.len().min(x.len());
    let dp = dst.as_mut_ptr();
    let xp = x.as_ptr();
    let av = _mm_set1_ps(a);
    let mut i = 0;
    while i + 4 <= n {
        let d = _mm_loadu_ps(dp.add(i));
        let v = _mm_loadu_ps(xp.add(i));
        _mm_storeu_ps(dp.add(i), _mm_sub_ps(d, _mm_mul_ps(av, v)));
        i += 4;
    }
    while i < n {
        *dp.add(i) -= a * *xp.add(i);
        i += 1;
    }
}

// ------------------------------------------- fused axpy_minus + colsq

/// Fused W pass: `dst[i] -= a * x[i]` plus `colsq[i] += new² − old²`
/// in f64, 8 f32 lanes / two f64 quad-vectors at a time.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_minus_colsq_avx2(dst: &mut [f32], a: f32, x: &[f32], colsq: &mut [f64]) {
    let n = dst.len().min(x.len()).min(colsq.len());
    let dp = dst.as_mut_ptr();
    let xp = x.as_ptr();
    let cp = colsq.as_mut_ptr();
    let av = _mm256_set1_ps(a);
    let mut i = 0;
    while i + 8 <= n {
        let old = _mm256_loadu_ps(dp.add(i));
        let v = _mm256_loadu_ps(xp.add(i));
        let new = _mm256_sub_ps(old, _mm256_mul_ps(av, v));
        _mm256_storeu_ps(dp.add(i), new);
        let old_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(old));
        let old_hi = _mm256_cvtps_pd(_mm256_extractf128_ps(old, 1));
        let new_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(new));
        let new_hi = _mm256_cvtps_pd(_mm256_extractf128_ps(new, 1));
        let c_lo = _mm256_loadu_pd(cp.add(i));
        let c_hi = _mm256_loadu_pd(cp.add(i + 4));
        let d_lo = _mm256_sub_pd(_mm256_mul_pd(new_lo, new_lo), _mm256_mul_pd(old_lo, old_lo));
        let d_hi = _mm256_sub_pd(_mm256_mul_pd(new_hi, new_hi), _mm256_mul_pd(old_hi, old_hi));
        _mm256_storeu_pd(cp.add(i), _mm256_add_pd(c_lo, d_lo));
        _mm256_storeu_pd(cp.add(i + 4), _mm256_add_pd(c_hi, d_hi));
        i += 8;
    }
    while i < n {
        let old = *dp.add(i) as f64;
        *dp.add(i) -= a * *xp.add(i);
        let new = *dp.add(i) as f64;
        *cp.add(i) += new * new - old * old;
        i += 1;
    }
}

/// Fused W pass, 4 f32 lanes / two f64 pair-vectors at a time.
#[target_feature(enable = "sse2")]
pub unsafe fn axpy_minus_colsq_sse2(dst: &mut [f32], a: f32, x: &[f32], colsq: &mut [f64]) {
    let n = dst.len().min(x.len()).min(colsq.len());
    let dp = dst.as_mut_ptr();
    let xp = x.as_ptr();
    let cp = colsq.as_mut_ptr();
    let av = _mm_set1_ps(a);
    let mut i = 0;
    while i + 4 <= n {
        let old = _mm_loadu_ps(dp.add(i));
        let v = _mm_loadu_ps(xp.add(i));
        let new = _mm_sub_ps(old, _mm_mul_ps(av, v));
        _mm_storeu_ps(dp.add(i), new);
        let old_lo = _mm_cvtps_pd(old);
        let old_hi = _mm_cvtps_pd(_mm_movehl_ps(old, old));
        let new_lo = _mm_cvtps_pd(new);
        let new_hi = _mm_cvtps_pd(_mm_movehl_ps(new, new));
        let c_lo = _mm_loadu_pd(cp.add(i));
        let c_hi = _mm_loadu_pd(cp.add(i + 2));
        let d_lo = _mm_sub_pd(_mm_mul_pd(new_lo, new_lo), _mm_mul_pd(old_lo, old_lo));
        let d_hi = _mm_sub_pd(_mm_mul_pd(new_hi, new_hi), _mm_mul_pd(old_hi, old_hi));
        _mm_storeu_pd(cp.add(i), _mm_add_pd(c_lo, d_lo));
        _mm_storeu_pd(cp.add(i + 2), _mm_add_pd(c_hi, d_hi));
        i += 4;
    }
    while i < n {
        let old = *dp.add(i) as f64;
        *dp.add(i) -= a * *xp.add(i);
        let new = *dp.add(i) as f64;
        *cp.add(i) += new * new - old * old;
        i += 1;
    }
}

// ------------------------------------------------------- colsq accum

/// `colsq[i] += row[i]²` in f64, 8 f32 lanes at a time.
#[target_feature(enable = "avx2")]
pub unsafe fn colsq_accum_avx2(colsq: &mut [f64], row: &[f32]) {
    let n = colsq.len().min(row.len());
    let cp = colsq.as_mut_ptr();
    let rp = row.as_ptr();
    let mut i = 0;
    while i + 8 <= n {
        let v = _mm256_loadu_ps(rp.add(i));
        let v_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(v));
        let v_hi = _mm256_cvtps_pd(_mm256_extractf128_ps(v, 1));
        let c_lo = _mm256_loadu_pd(cp.add(i));
        let c_hi = _mm256_loadu_pd(cp.add(i + 4));
        _mm256_storeu_pd(cp.add(i), _mm256_add_pd(c_lo, _mm256_mul_pd(v_lo, v_lo)));
        _mm256_storeu_pd(cp.add(i + 4), _mm256_add_pd(c_hi, _mm256_mul_pd(v_hi, v_hi)));
        i += 8;
    }
    while i < n {
        let v = *rp.add(i) as f64;
        *cp.add(i) += v * v;
        i += 1;
    }
}

/// `colsq[i] += row[i]²` in f64, 4 f32 lanes at a time.
#[target_feature(enable = "sse2")]
pub unsafe fn colsq_accum_sse2(colsq: &mut [f64], row: &[f32]) {
    let n = colsq.len().min(row.len());
    let cp = colsq.as_mut_ptr();
    let rp = row.as_ptr();
    let mut i = 0;
    while i + 4 <= n {
        let v = _mm_loadu_ps(rp.add(i));
        let v_lo = _mm_cvtps_pd(v);
        let v_hi = _mm_cvtps_pd(_mm_movehl_ps(v, v));
        let c_lo = _mm_loadu_pd(cp.add(i));
        let c_hi = _mm_loadu_pd(cp.add(i + 2));
        _mm_storeu_pd(cp.add(i), _mm_add_pd(c_lo, _mm_mul_pd(v_lo, v_lo)));
        _mm_storeu_pd(cp.add(i + 2), _mm_add_pd(c_hi, _mm_mul_pd(v_hi, v_hi)));
        i += 4;
    }
    while i < n {
        let v = *rp.add(i) as f64;
        *cp.add(i) += v * v;
        i += 1;
    }
}

// ------------------------------------------------------------- scale

/// `dst[i] *= s`, 8 lanes at a time.
#[target_feature(enable = "avx2")]
pub unsafe fn scale_avx2(dst: &mut [f32], s: f32) {
    let n = dst.len();
    let dp = dst.as_mut_ptr();
    let sv = _mm256_set1_ps(s);
    let mut i = 0;
    while i + 8 <= n {
        let d = _mm256_loadu_ps(dp.add(i));
        _mm256_storeu_ps(dp.add(i), _mm256_mul_ps(d, sv));
        i += 8;
    }
    while i < n {
        *dp.add(i) *= s;
        i += 1;
    }
}

/// `dst[i] *= s`, 4 lanes at a time.
#[target_feature(enable = "sse2")]
pub unsafe fn scale_sse2(dst: &mut [f32], s: f32) {
    let n = dst.len();
    let dp = dst.as_mut_ptr();
    let sv = _mm_set1_ps(s);
    let mut i = 0;
    while i + 4 <= n {
        let d = _mm_loadu_ps(dp.add(i));
        _mm_storeu_ps(dp.add(i), _mm_mul_ps(d, sv));
        i += 4;
    }
    while i < n {
        *dp.add(i) *= s;
        i += 1;
    }
}

// --------------------------------------------------------- quad axpy

/// GEMM quad-row kernel with the scalar left-to-right addition tree:
/// `dst[j] += ((a0·b0[j] + a1·b1[j]) + a2·b2[j]) + a3·b3[j]`.
#[target_feature(enable = "avx2")]
pub unsafe fn quad_axpy_avx2(
    dst: &mut [f32],
    a: [f32; 4],
    b0: &[f32],
    b1: &[f32],
    b2: &[f32],
    b3: &[f32],
) {
    let n = dst.len().min(b0.len()).min(b1.len()).min(b2.len()).min(b3.len());
    let dp = dst.as_mut_ptr();
    let (p0, p1, p2, p3) = (b0.as_ptr(), b1.as_ptr(), b2.as_ptr(), b3.as_ptr());
    let a0 = _mm256_set1_ps(a[0]);
    let a1 = _mm256_set1_ps(a[1]);
    let a2 = _mm256_set1_ps(a[2]);
    let a3 = _mm256_set1_ps(a[3]);
    let mut j = 0;
    while j + 8 <= n {
        let m0 = _mm256_mul_ps(a0, _mm256_loadu_ps(p0.add(j)));
        let m1 = _mm256_mul_ps(a1, _mm256_loadu_ps(p1.add(j)));
        let m2 = _mm256_mul_ps(a2, _mm256_loadu_ps(p2.add(j)));
        let m3 = _mm256_mul_ps(a3, _mm256_loadu_ps(p3.add(j)));
        let t = _mm256_add_ps(_mm256_add_ps(_mm256_add_ps(m0, m1), m2), m3);
        let d = _mm256_loadu_ps(dp.add(j));
        _mm256_storeu_ps(dp.add(j), _mm256_add_ps(d, t));
        j += 8;
    }
    while j < n {
        *dp.add(j) += a[0] * *p0.add(j) + a[1] * *p1.add(j) + a[2] * *p2.add(j) + a[3] * *p3.add(j);
        j += 1;
    }
}

/// GEMM quad-row kernel, 4 lanes at a time.
#[target_feature(enable = "sse2")]
pub unsafe fn quad_axpy_sse2(
    dst: &mut [f32],
    a: [f32; 4],
    b0: &[f32],
    b1: &[f32],
    b2: &[f32],
    b3: &[f32],
) {
    let n = dst.len().min(b0.len()).min(b1.len()).min(b2.len()).min(b3.len());
    let dp = dst.as_mut_ptr();
    let (p0, p1, p2, p3) = (b0.as_ptr(), b1.as_ptr(), b2.as_ptr(), b3.as_ptr());
    let a0 = _mm_set1_ps(a[0]);
    let a1 = _mm_set1_ps(a[1]);
    let a2 = _mm_set1_ps(a[2]);
    let a3 = _mm_set1_ps(a[3]);
    let mut j = 0;
    while j + 4 <= n {
        let m0 = _mm_mul_ps(a0, _mm_loadu_ps(p0.add(j)));
        let m1 = _mm_mul_ps(a1, _mm_loadu_ps(p1.add(j)));
        let m2 = _mm_mul_ps(a2, _mm_loadu_ps(p2.add(j)));
        let m3 = _mm_mul_ps(a3, _mm_loadu_ps(p3.add(j)));
        let t = _mm_add_ps(_mm_add_ps(_mm_add_ps(m0, m1), m2), m3);
        let d = _mm_loadu_ps(dp.add(j));
        _mm_storeu_ps(dp.add(j), _mm_add_ps(d, t));
        j += 4;
    }
    while j < n {
        *dp.add(j) += a[0] * *p0.add(j) + a[1] * *p1.add(j) + a[2] * *p2.add(j) + a[3] * *p3.add(j);
        j += 1;
    }
}

// ---------------------------------------------- SPD column-block solve

/// Column-block triangular solves for the SPD inverse, AVX2 (8 lanes):
/// lane `l` runs the scalar forward/backward column solve for column
/// `j0 + l`, all lanes in lockstep over rows. Lanes whose pivot row
/// lies below the current row accumulate exact `±0` terms until it
/// (IEEE `+0 + ±0 = +0`), so every lane's accumulation order is the
/// scalar column solve's, term for term — the bit-identity argument in
/// DESIGN.md §14. Rows `i < j0 + l` of `x` and lanes `≥ n − j0` are
/// garbage the caller never scatters.
#[target_feature(enable = "avx2")]
pub unsafe fn spd_solve_lanes_avx2(
    ld: &[f32],
    ltd: &[f32],
    n: usize,
    j0: usize,
    y: &mut [f32],
    x: &mut [f32],
) {
    const L: usize = 8;
    debug_assert!(ld.len() >= n * n && ltd.len() >= n * n);
    debug_assert!(y.len() >= n * L && x.len() >= n * L);
    let neg = _mm256_set1_ps(-0.0);
    let lp = ld.as_ptr();
    let tp = ltd.as_ptr();
    let yp = y.as_mut_ptr();
    let xp = x.as_mut_ptr();
    // Forward: solve L y = e_{j0+l} per lane over rows j0..n.
    for i in j0..n {
        let mut acc = _mm256_setzero_ps();
        for k in j0..i {
            let c = _mm256_set1_ps(*lp.add(i * n + k));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(c, _mm256_loadu_ps(yp.add(k * L))));
        }
        let piv = _mm256_set1_ps(*lp.add(i * n + i));
        _mm256_storeu_ps(yp.add(i * L), _mm256_div_ps(_mm256_xor_ps(acc, neg), piv));
        if i - j0 < L {
            // Pivot row for lane i − j0: y[i] = 1 / L[i,i], exactly as
            // the scalar solve seeds its unit RHS.
            *yp.add(i * L + (i - j0)) = 1.0 / *lp.add(i * n + i);
        }
    }
    // Backward: solve Lᵀ x = y per lane over rows n−1..=j0.
    for i in (j0..n).rev() {
        let mut s = _mm256_loadu_ps(yp.add(i * L));
        for k in i + 1..n {
            let c = _mm256_set1_ps(*tp.add(i * n + k));
            s = _mm256_sub_ps(s, _mm256_mul_ps(c, _mm256_loadu_ps(xp.add(k * L))));
        }
        let piv = _mm256_set1_ps(*lp.add(i * n + i));
        _mm256_storeu_ps(xp.add(i * L), _mm256_div_ps(s, piv));
    }
}

/// Column-block triangular solves, SSE2 (4 lanes). Same construction
/// as [`spd_solve_lanes_avx2`].
#[target_feature(enable = "sse2")]
pub unsafe fn spd_solve_lanes_sse2(
    ld: &[f32],
    ltd: &[f32],
    n: usize,
    j0: usize,
    y: &mut [f32],
    x: &mut [f32],
) {
    const L: usize = 4;
    debug_assert!(ld.len() >= n * n && ltd.len() >= n * n);
    debug_assert!(y.len() >= n * L && x.len() >= n * L);
    let neg = _mm_set1_ps(-0.0);
    let lp = ld.as_ptr();
    let tp = ltd.as_ptr();
    let yp = y.as_mut_ptr();
    let xp = x.as_mut_ptr();
    for i in j0..n {
        let mut acc = _mm_setzero_ps();
        for k in j0..i {
            let c = _mm_set1_ps(*lp.add(i * n + k));
            acc = _mm_add_ps(acc, _mm_mul_ps(c, _mm_loadu_ps(yp.add(k * L))));
        }
        let piv = _mm_set1_ps(*lp.add(i * n + i));
        _mm_storeu_ps(yp.add(i * L), _mm_div_ps(_mm_xor_ps(acc, neg), piv));
        if i - j0 < L {
            *yp.add(i * L + (i - j0)) = 1.0 / *lp.add(i * n + i);
        }
    }
    for i in (j0..n).rev() {
        let mut s = _mm_loadu_ps(yp.add(i * L));
        for k in i + 1..n {
            let c = _mm_set1_ps(*tp.add(i * n + k));
            s = _mm_sub_ps(s, _mm_mul_ps(c, _mm_loadu_ps(xp.add(k * L))));
        }
        let piv = _mm_set1_ps(*lp.add(i * n + i));
        _mm_storeu_ps(xp.add(i * L), _mm_div_ps(s, piv));
    }
}
