//! Pruning configuration and report types, shared by the session API
//! and everything downstream of it.
//!
//! The ZipLM pipeline (paper Fig. 1 — capture → databases → SPDY →
//! apply → family) lives behind the typed
//! [`crate::session::CompressionSession`] API; the algorithmic bodies
//! are the free functions in [`crate::session::pipeline`]. The
//! `#[deprecated]` free-function shims that used to live here (PR 3's
//! one-PR compatibility layer) are gone — this module now carries only
//! the *types* both layers speak: [`PruneCfg`], [`PruneReport`],
//! [`Hessians`], [`StageResult`].

use crate::compress::CompressionProfile;
use crate::models::ModelState;
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct PruneCfg {
    /// number of calibration samples (paper default 2048; Table 4
    /// studies sensitivity down to 4)
    pub calib_samples: usize,
    pub damp_frac: f32,
    pub spdy: SpdyCfgLite,
    /// use the HLO (Pallas) backend; false = native mirror (tests)
    pub use_hlo: bool,
    /// "speedup" (ZipLM) or "sparsity" (Fig. 4 ablation baseline mode)
    pub target_mode: TargetMode,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TargetMode {
    Speedup,
    Sparsity,
}

#[derive(Clone, Debug)]
pub struct SpdyCfgLite {
    pub iters: usize,
    pub seed: u64,
}

impl Default for PruneCfg {
    fn default() -> Self {
        PruneCfg {
            calib_samples: 256,
            damp_frac: 0.01,
            spdy: SpdyCfgLite { iters: 120, seed: 7 },
            use_hlo: true,
            target_mode: TargetMode::Speedup,
        }
    }
}

#[derive(Clone, Debug)]
pub struct PruneReport {
    pub target: f64,
    pub est_speedup: f64,
    /// legacy structural anatomy `(heads, ffn_cols)` per layer —
    /// derivable from `choices`; kept for the raw-profile shims and
    /// the on-disk stage checkpoints
    pub layer_profile: Vec<(usize, usize)>,
    /// typed per-module choices (prune-only for the classic pipeline;
    /// mixed-axis for [`crate::session::pipeline::compound_to_target`])
    pub choices: CompressionProfile,
    pub calib_loss: f64,
    pub obs_dispatches: usize,
}

/// Configuration of the compound choice lattice (DESIGN.md §13): which
/// non-pruning axes [`crate::session::pipeline::choice_problem`] adds
/// on top of the OBS pruning levels.
#[derive(Clone, Debug)]
pub struct CompoundCfg {
    /// add int8 choices (dense-quant plus prune-then-quant per level)
    pub quant: bool,
    /// low-rank FFN ranks to offer; empty = derive `[3d/4, d/2, d/4]`
    /// from the module's row count
    pub ranks: Vec<usize>,
}

impl Default for CompoundCfg {
    fn default() -> Self {
        CompoundCfg { quant: true, ranks: Vec::new() }
    }
}

/// Accumulated calibration Hessians: one XX^T per prunable module.
pub struct Hessians {
    pub attn: Vec<Tensor>, // per layer [d_attn, d_attn]
    pub ffn: Vec<Tensor>,  // per layer [d_ff, d_ff]
}

/// One gradual pruning stage: the certified report, the fine-tuned
/// state, and its final training loss.
pub struct StageResult {
    pub report: PruneReport,
    pub state: ModelState,
    pub final_train_loss: f64,
}
