//! Pruning configuration/report types, plus the legacy free-function
//! pipeline as deprecated shims.
//!
//! The ZipLM pipeline (paper Fig. 1 — capture → databases → SPDY →
//! apply → family) now lives behind the typed
//! [`crate::session::CompressionSession`] API; the algorithmic bodies
//! are in [`crate::session::pipeline`]. The free functions here are
//! one-PR compatibility shims so downstream diffs stay reviewable —
//! they delegate directly and will be removed next PR. The *types*
//! ([`PruneCfg`], [`PruneReport`], [`Hessians`], [`StageResult`], …)
//! are not deprecated; the session API shares them.

use anyhow::Result;

use crate::data::Dataset;
use crate::env::InferenceEnv;
use crate::models::ModelState;
use crate::runtime::{Engine, ModelInfo, TaskInfo};
use crate::session::pipeline;
use crate::spdy::SpdyProblem;
use crate::tensor::Tensor;
use crate::train::TrainCfg;
use crate::ziplm::ModuleDb;

#[derive(Clone, Debug)]
pub struct PruneCfg {
    /// number of calibration samples (paper default 2048; Table 4
    /// studies sensitivity down to 4)
    pub calib_samples: usize,
    pub damp_frac: f32,
    pub spdy: SpdyCfgLite,
    /// use the HLO (Pallas) backend; false = native mirror (tests)
    pub use_hlo: bool,
    /// "speedup" (ZipLM) or "sparsity" (Fig. 4 ablation baseline mode)
    pub target_mode: TargetMode,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TargetMode {
    Speedup,
    Sparsity,
}

#[derive(Clone, Debug)]
pub struct SpdyCfgLite {
    pub iters: usize,
    pub seed: u64,
}

impl Default for PruneCfg {
    fn default() -> Self {
        PruneCfg {
            calib_samples: 256,
            damp_frac: 0.01,
            spdy: SpdyCfgLite { iters: 120, seed: 7 },
            use_hlo: true,
            target_mode: TargetMode::Speedup,
        }
    }
}

#[derive(Clone, Debug)]
pub struct PruneReport {
    pub target: f64,
    pub est_speedup: f64,
    pub layer_profile: Vec<(usize, usize)>,
    pub calib_loss: f64,
    pub obs_dispatches: usize,
}

/// Accumulated calibration Hessians: one XX^T per prunable module.
pub struct Hessians {
    pub attn: Vec<Tensor>, // per layer [d_attn, d_attn]
    pub ffn: Vec<Tensor>,  // per layer [d_ff, d_ff]
}

/// One gradual pruning stage: the certified report, the fine-tuned
/// state, and its final training loss.
pub struct StageResult {
    pub report: PruneReport,
    pub state: ModelState,
    pub final_train_loss: f64,
}

// ------------------------------------------------------------- shims
//
// Legacy free-function pipeline. Each shim delegates to
// `session::pipeline`; migrate to `CompressionSession` (the shims are
// exercised only by the legacy-vs-session equivalence tests).

/// Run the calib artifact over `n_samples` and accumulate XX^T.
#[deprecated(
    note = "use session::CompressionSession::capture (or session::pipeline::capture_hessians)"
)]
pub fn capture_hessians(
    engine: &Engine,
    state: &ModelState,
    data: &Dataset,
    n_samples: usize,
) -> Result<Hessians> {
    pipeline::capture_hessians(engine, state, data, n_samples)
}

/// Build all 2L module databases (parallel fan-out).
#[deprecated(note = "use session::Captured::build_dbs (or session::pipeline::build_databases)")]
pub fn build_databases(
    engine: &Engine,
    state: &ModelState,
    hs: &Hessians,
    cfg: &PruneCfg,
) -> Result<Vec<ModuleDb>> {
    pipeline::build_databases(engine, state, hs, cfg)
}

/// Assemble the SPDY problem from databases + an inference environment.
#[deprecated(note = "use session::Databases::solve (or session::pipeline::spdy_problem)")]
pub fn spdy_problem(
    dbs: &[ModuleDb],
    env: &InferenceEnv,
    minfo: &ModelInfo,
    mode: TargetMode,
) -> SpdyProblem {
    pipeline::spdy_problem(dbs, env, minfo, mode)
}

/// Apply a chosen profile: write snapshot weights + kill masks.
#[deprecated(note = "use session::Solved::apply (or session::pipeline::apply_profile)")]
pub fn apply_profile(
    state: &mut ModelState,
    dbs: &[ModuleDb],
    profile: &[usize],
    minfo: &ModelInfo,
    tinfo: &TaskInfo,
) -> Result<()> {
    pipeline::apply_profile(state, dbs, profile, minfo, tinfo)
}

/// One pruning stage: Hessians → databases → SPDY → apply.
#[deprecated(note = "use session::CompressionSession::oneshot")]
pub fn prune_to_target(
    engine: &Engine,
    state: &mut ModelState,
    data: &Dataset,
    env: &InferenceEnv,
    dense_cost: f64,
    target: f64,
    cfg: &PruneCfg,
) -> Result<PruneReport> {
    pipeline::prune_to_target(engine, state, data, env, dense_cost, target, cfg)
}

/// Gradual pruning: the full family pipeline (paper Fig. 1).
#[deprecated(note = "use session::CompressionSession::run")]
#[allow(clippy::too_many_arguments)]
pub fn gradual(
    engine: &Engine,
    state: ModelState,
    data: &Dataset,
    env: &InferenceEnv,
    targets: &[f64],
    prune_cfg: &PruneCfg,
    train_cfg: &TrainCfg,
    teacher: Option<Vec<f32>>,
) -> Result<Vec<StageResult>> {
    pipeline::gradual(engine, state, data, env, targets, prune_cfg, train_cfg, teacher)
}
