//! Pruning drivers: the ZipLM pipeline (paper Fig. 1).
//!
//!   1. capture calibration Hessians through the masked model,
//!   2. build per-module databases (ziplm/) via the OBS kernels — all
//!      2L modules fan out in parallel across the machine,
//!   3. structured SPDY search (spdy/) against the latency table for
//!      the next speedup target,
//!   4. apply the chosen profile (masks + OBS-updated weights),
//!   5. gradual mode: fine-tune with token distillation and continue to
//!      the next target — one run emits the whole model family.
//!
//! One-shot (post-training) mode is steps 1–4 only (paper §4.3).

use anyhow::{anyhow, Result};

use crate::data::Dataset;
use crate::eval::{calib_loss, mask_literals};
use crate::latency::LatencyTable;
use crate::models::ModelState;
use crate::runtime::{lit_f32_shaped, lit_i32, lit_to_f32, Engine, ModelInfo, TaskInfo};
use crate::spdy::{self, LevelOpt, ModuleLevels, SearchCfg, SpdyProblem};
use crate::tensor::Tensor;
use crate::train::{TrainCfg, Trainer};
use crate::util::threadpool::parallel_tasks;
use crate::ziplm::{
    assemble_hessian, build_module_db, build_module_db_masked, HloBackend, ModuleDb,
    NativeBackend, ObsOps,
};

#[derive(Clone, Debug)]
pub struct PruneCfg {
    /// number of calibration samples (paper default 2048; Table 4
    /// studies sensitivity down to 4)
    pub calib_samples: usize,
    pub damp_frac: f32,
    pub spdy: SpdyCfgLite,
    /// use the HLO (Pallas) backend; false = native mirror (tests)
    pub use_hlo: bool,
    /// "speedup" (ZipLM) or "sparsity" (Fig. 4 ablation baseline mode)
    pub target_mode: TargetMode,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TargetMode {
    Speedup,
    Sparsity,
}

#[derive(Clone, Debug)]
pub struct SpdyCfgLite {
    pub iters: usize,
    pub seed: u64,
}

impl Default for PruneCfg {
    fn default() -> Self {
        PruneCfg {
            calib_samples: 256,
            damp_frac: 0.01,
            spdy: SpdyCfgLite { iters: 120, seed: 7 },
            use_hlo: true,
            target_mode: TargetMode::Speedup,
        }
    }
}

#[derive(Clone, Debug)]
pub struct PruneReport {
    pub target: f64,
    pub est_speedup: f64,
    pub layer_profile: Vec<(usize, usize)>,
    pub calib_loss: f64,
    pub obs_dispatches: usize,
}

/// Accumulated calibration Hessians: one XX^T per prunable module.
pub struct Hessians {
    pub attn: Vec<Tensor>, // per layer [d_attn, d_attn]
    pub ffn: Vec<Tensor>,  // per layer [d_ff, d_ff]
}

/// Run the calib artifact over `n_samples` and accumulate XX^T.
pub fn capture_hessians(
    engine: &Engine,
    state: &ModelState,
    data: &Dataset,
    n_samples: usize,
) -> Result<Hessians> {
    let minfo = engine.manifest.model(&state.model).clone();
    let tinfo = engine.manifest.task(&state.model, &state.task).clone();
    let b = engine.manifest.batch_calib;
    let art = format!("{}__{}__calib", state.model, state.task);
    let (hm, fm) = mask_literals(state)?;
    let params = lit_f32_shaped(&[tinfo.n_params], &state.params)?;
    let da = minfo.d_attn();
    let f = minfo.d_ff;
    let l = minfo.n_layers;
    let mut attn = vec![Tensor::zeros(&[da, da]); l];
    let mut ffn = vec![Tensor::zeros(&[f, f]); l];
    let mut i = 0;
    while i < n_samples.max(b) {
        let idxs: Vec<usize> = (i..i + b).collect();
        let (ids, _) = data.batch(&idxs);
        let out = engine.run(
            &art,
            &[params.clone(), lit_i32(&[b, data.seq_len], &ids)?, hm.clone(), fm.clone()],
        )?;
        let ha = lit_to_f32(&out[0])?; // [L, da, da]
        let hf = lit_to_f32(&out[1])?; // [L, f, f]
        for li in 0..l {
            let sa = &ha[li * da * da..(li + 1) * da * da];
            for (dst, src) in attn[li].data.iter_mut().zip(sa) {
                *dst += src;
            }
            let sf = &hf[li * f * f..(li + 1) * f * f];
            for (dst, src) in ffn[li].data.iter_mut().zip(sf) {
                *dst += src;
            }
        }
        i += b;
    }
    Ok(Hessians { attn, ffn })
}

/// Build all 2L module databases. Module order: (attn, fc) per layer.
///
/// Modules are independent once the per-module Hessian is accumulated,
/// so every (layer, attn|fc) build — including its O(d³) Hessian
/// inversion — runs as one [`parallel_tasks`] job, capped at the
/// hardware parallelism: a full per-layer database build saturates
/// the machine instead of running layer-by-layer.
pub fn build_databases(
    engine: &Engine,
    state: &ModelState,
    hs: &Hessians,
    cfg: &PruneCfg,
) -> Result<Vec<ModuleDb>> {
    let minfo = engine.manifest.model(&state.model).clone();
    let tinfo = engine.manifest.task(&state.model, &state.task).clone();
    let n_modules = 2 * minfo.n_layers;
    let dbs = parallel_tasks(n_modules, |m| -> Result<ModuleDb> {
        let (l, is_attn) = (m / 2, m % 2 == 0);
        if is_attn {
            let w0 = state.attn_w_paper(&tinfo, l)?;
            let (h, hinv) = assemble_hessian(&hs.attn[l], cfg.damp_frac)?;
            let cur_heads = state.masks.heads_alive(l);
            let levels: Vec<usize> = (0..=cur_heads).rev().collect();
            if cfg.use_hlo {
                let mut ops = HloBackend::attn(engine, &state.model)?;
                build_db_with_mask(&mut ops, l, true, &w0, &hinv, &h, &levels, state.masks.head_row(l))
            } else {
                let mut ops = NativeBackend::new(minfo.d_head);
                build_db_with_mask(&mut ops, l, true, &w0, &hinv, &h, &levels, state.masks.head_row(l))
            }
        } else {
            let w0 = state.fc_w_paper(&tinfo, l)?;
            let (h, hinv) = assemble_hessian(&hs.ffn[l], cfg.damp_frac)?;
            let cur = state.masks.ffn_alive(l);
            let mut levels: Vec<usize> = vec![cur];
            levels.extend(minfo.ffn_ladder.iter().copied().filter(|&x| x < cur));
            if cfg.use_hlo {
                let mut ops = HloBackend::fc(engine, &state.model)?;
                build_db_with_mask(&mut ops, l, false, &w0, &hinv, &h, &levels, state.masks.ffn_row(l))
            } else {
                let mut ops = NativeBackend::new(1);
                build_db_with_mask(&mut ops, l, false, &w0, &hinv, &h, &levels, state.masks.ffn_row(l))
            }
        }
    });
    dbs.into_iter().collect()
}

/// build_module_db wrapper that respects an existing structural mask
/// (gradual pruning continues from the current model).
#[allow(clippy::too_many_arguments)]
fn build_db_with_mask(
    ops: &mut dyn ObsOps,
    layer: usize,
    is_attn: bool,
    w0: &Tensor,
    hinv: &Tensor,
    h: &Tensor,
    levels: &[usize],
    mask_row: &[f32],
) -> Result<ModuleDb> {
    let g = ops.group();
    let n_structs = w0.cols() / g;
    let already_dead: Vec<usize> =
        (0..n_structs).filter(|&j| mask_row.get(j).copied().unwrap_or(1.0) == 0.0).collect();
    if already_dead.is_empty() {
        return build_module_db(ops, layer, is_attn, w0, hinv, h, levels);
    }
    // Re-anchor: treat currently-alive structures as the dense level.
    let mut db = build_module_db_masked(ops, layer, is_attn, w0, hinv, h, levels, &already_dead)?;
    for lvl in &mut db.levels {
        // make dead lists absolute (include pre-existing dead)
        let mut dead = already_dead.clone();
        dead.extend(lvl.dead.iter().copied());
        lvl.dead = dead;
    }
    Ok(db)
}

/// Module parameter counts for sparsity-target mode (Fig. 4).
fn module_params(minfo: &ModelInfo, is_attn: bool, remaining: usize) -> f64 {
    if is_attn {
        // q,k,v,o weights+biases per head
        (remaining * minfo.d_head * minfo.d_model * 4 + remaining * minfo.d_head * 3) as f64
    } else {
        (remaining * minfo.d_model * 2 + remaining) as f64
    }
}

/// Assemble the SPDY problem from databases + latency table.
pub fn spdy_problem(
    dbs: &[ModuleDb],
    table: &LatencyTable,
    minfo: &ModelInfo,
    mode: TargetMode,
) -> SpdyProblem {
    let modules = dbs
        .iter()
        .map(|db| ModuleLevels {
            layer: db.layer,
            is_attn: db.is_attn,
            options: db
                .levels
                .iter()
                .map(|lvl| LevelOpt {
                    remaining: lvl.remaining,
                    cost: match mode {
                        TargetMode::Speedup => {
                            if db.is_attn {
                                table.attn_time(lvl.remaining)
                            } else {
                                table.mlp_time(lvl.remaining)
                            }
                        }
                        TargetMode::Sparsity => module_params(minfo, db.is_attn, lvl.remaining),
                    },
                    prior: lvl.prior,
                })
                .collect(),
        })
        .collect();
    SpdyProblem {
        modules,
        overhead: match mode {
            TargetMode::Speedup => table.overhead,
            TargetMode::Sparsity => 0.0,
        },
    }
}

/// Apply a chosen profile: write snapshot weights + kill masks.
pub fn apply_profile(
    state: &mut ModelState,
    dbs: &[ModuleDb],
    profile: &[usize],
    minfo: &ModelInfo,
    tinfo: &TaskInfo,
) -> Result<()> {
    for (db, &li) in dbs.iter().zip(profile) {
        let lvl = &db.levels[li];
        if db.is_attn {
            state.set_attn_w_paper(tinfo, db.layer, &lvl.w, &lvl.dead, minfo.d_head)?;
            for &h in &lvl.dead {
                state.masks.kill_head(db.layer, h);
            }
        } else {
            state.set_fc_w_paper(tinfo, db.layer, &lvl.w, &lvl.dead)?;
            for &c in &lvl.dead {
                state.masks.kill_ffn_col(db.layer, c);
            }
        }
    }
    Ok(())
}

/// One pruning stage: Hessians → databases → SPDY → apply.
/// `dense_time` is the original dense model's latency (speedup anchor).
pub fn prune_to_target(
    engine: &Engine,
    state: &mut ModelState,
    data: &Dataset,
    table: &LatencyTable,
    dense_cost: f64,
    target: f64,
    cfg: &PruneCfg,
) -> Result<PruneReport> {
    let minfo = engine.manifest.model(&state.model).clone();
    let tinfo = engine.manifest.task(&state.model, &state.task).clone();
    let hs = capture_hessians(engine, state, data, cfg.calib_samples)?;
    let dbs = build_databases(engine, state, &hs, cfg)?;
    let problem = spdy_problem(&dbs, table, &minfo, cfg.target_mode);
    let budget = dense_cost / target;
    if problem.min_cost() > budget {
        return Err(anyhow!(
            "target {target}x infeasible: min cost {:.3e} > budget {:.3e}",
            problem.min_cost(),
            budget
        ));
    }
    let base = state.clone();
    let mut evals = 0usize;
    let search_cfg = SearchCfg { iters: cfg.spdy.iters, seed: cfg.spdy.seed, ..Default::default() };
    let (profile, best_loss) = spdy::search(&problem, budget, &search_cfg, |prof| {
        evals += 1;
        let mut cand = base.clone();
        if apply_profile(&mut cand, &dbs, prof, &minfo, &tinfo).is_err() {
            return f64::INFINITY;
        }
        calib_loss(engine, &cand, data, cfg.calib_samples.min(128)).unwrap_or(f64::INFINITY)
    })
    .ok_or_else(|| anyhow!("SPDY found no feasible profile for {target}x"))?;
    apply_profile(state, &dbs, &profile, &minfo, &tinfo)?;
    let layer_profile = problem.as_layer_profile(&profile);
    let est = match cfg.target_mode {
        TargetMode::Speedup => dense_cost / problem.profile_cost(&profile),
        TargetMode::Sparsity => {
            // report the latency-table speedup this sparsity happens to give
            table.dense_time(minfo.n_layers) / table.model_time(&layer_profile)
        }
    };
    crate::zlog!(
        "info",
        "pruned to {target}x: est_speedup={est:.2} profile={layer_profile:?} candidates={evals}"
    );
    Ok(PruneReport {
        target,
        est_speedup: est,
        layer_profile,
        calib_loss: best_loss,
        obs_dispatches: 0,
    })
}

/// Gradual pruning: the full family pipeline (paper Fig. 1).
pub struct StageResult {
    pub report: PruneReport,
    pub state: ModelState,
    pub final_train_loss: f64,
}

#[allow(clippy::too_many_arguments)]
pub fn gradual(
    engine: &Engine,
    mut state: ModelState,
    data: &Dataset,
    table: &LatencyTable,
    targets: &[f64],
    prune_cfg: &PruneCfg,
    train_cfg: &TrainCfg,
    teacher: Option<Vec<f32>>,
) -> Result<Vec<StageResult>> {
    let tinfo = engine.manifest.task(&state.model, &state.task).clone();
    let minfo = engine.manifest.model(&state.model).clone();
    let dense_cost = match prune_cfg.target_mode {
        TargetMode::Speedup => table.dense_time(minfo.n_layers),
        TargetMode::Sparsity => {
            (0..minfo.n_layers)
                .map(|_| module_params(&minfo, true, minfo.n_heads) + module_params(&minfo, false, minfo.d_ff))
                .sum()
        }
    };
    let mut trainer = Trainer::new(engine, tinfo.n_params, teacher);
    let mut out = Vec::new();
    for &target in targets {
        let report = prune_to_target(engine, &mut state, data, table, dense_cost, target, prune_cfg)?;
        trainer.reset_moments();
        let final_loss = trainer.train(&mut state, data, train_cfg)?;
        out.push(StageResult { report, state: state.clone(), final_train_loss: final_loss });
    }
    Ok(out)
}
