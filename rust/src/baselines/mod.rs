//! Baseline compression methods the paper compares against, re-built on
//! the same substrate (DESIGN.md §3 maps each to its literature family):
//!
//! * [`magnitude_for_speedup`] — structured magnitude pruning (no OBS
//!   update), greedy by magnitude-per-latency-saved;
//! * [`layer_drop_for_speedup`] — Poor-Man's-BERT / oBERT-style whole
//!   layer dropping;
//! * [`fisher_oneshot`] — Kwon et al.-style post-training pruning:
//!   diagonal (Fisher/OBD) saliencies, latency-constrained mask search
//!   via the same DP, and a single least-squares weight reconstruction
//!   at the END (vs ZipLM's continuous updates — exactly the difference
//!   §4.3 credits for the gap);
//! * distillation students (half-depth DistilBERT/DistilGPT2-like and
//!   width-scaled Well-Read-Students-like) are mask constructors here,
//!   trained with KD by the experiment drivers.

use anyhow::Result;

use crate::env::{CostModel, InferenceEnv};
use crate::models::ModelState;
use crate::pruner::Hessians;
use crate::runtime::{ModelInfo, TaskInfo};
use crate::spdy::{self, LevelOpt, ModuleLevels, SpdyProblem};
use crate::tensor::{linalg, Tensor};

/// Squared L2 magnitude of each structure (column group) of W_paper.
fn structure_magnitudes(w: &Tensor, g: usize) -> Vec<f64> {
    let n = w.cols() / g;
    let mut out = vec![0f64; n];
    for i in 0..w.rows() {
        let row = w.row(i);
        for j in 0..n {
            for c in j * g..(j + 1) * g {
                out[j] += (row[c] as f64).powi(2);
            }
        }
    }
    out
}

/// Structured magnitude pruning to a speedup target: repeatedly remove
/// the structure with the smallest magnitude / latency-saved ratio.
/// No weight updates — the classic weakness ZipLM's Eq. 3 fixes.
pub fn magnitude_for_speedup(
    state: &mut ModelState,
    minfo: &ModelInfo,
    tinfo: &TaskInfo,
    env: &InferenceEnv,
    target: f64,
) -> Result<Vec<(usize, usize)>> {
    let dense = env.dense_time(minfo.n_layers);
    let budget = dense / target;
    // candidate list: (layer, is_attn, index, magnitude)
    let mut mags: Vec<(usize, bool, usize, f64)> = Vec::new();
    for l in 0..minfo.n_layers {
        let wa = state.attn_w_paper(tinfo, l)?;
        for (j, m) in structure_magnitudes(&wa, minfo.d_head).into_iter().enumerate() {
            mags.push((l, true, j, m));
        }
        let wf = state.fc_w_paper(tinfo, l)?;
        for (j, m) in structure_magnitudes(&wf, 1).into_iter().enumerate() {
            mags.push((l, false, j, m));
        }
    }
    mags.sort_by(|a, b| a.3.total_cmp(&b.3));
    let mut profile: Vec<(usize, usize)> =
        (0..minfo.n_layers).map(|_| (minfo.n_heads, minfo.d_ff)).collect();
    let mut k = 0;
    while env.model_time(&profile) > budget && k < mags.len() {
        let (l, is_attn, j, _) = mags[k];
        k += 1;
        if is_attn {
            if profile[l].0 == 0 {
                continue;
            }
            profile[l].0 -= 1;
            state.masks.kill_head(l, j);
        } else {
            if profile[l].1 == 0 {
                continue;
            }
            profile[l].1 -= 1;
            state.masks.kill_ffn_col(l, j);
        }
    }
    // zero the pruned weights (magnitude pruning has no compensation)
    crate::train::rezero_dead(state, tinfo, minfo);
    Ok(profile)
}

/// Whole-layer dropping to a speedup target. Order: alternating layers
/// first (DistilBERT heuristic), then top-down.
pub fn layer_drop_for_speedup(
    state: &mut ModelState,
    minfo: &ModelInfo,
    tinfo: &TaskInfo,
    env: &InferenceEnv,
    target: f64,
) -> Result<Vec<(usize, usize)>> {
    let dense = env.dense_time(minfo.n_layers);
    let budget = dense / target;
    let mut order: Vec<usize> = (0..minfo.n_layers).skip(1).step_by(2).collect();
    order.extend((0..minfo.n_layers).step_by(2).rev());
    let mut profile: Vec<(usize, usize)> =
        (0..minfo.n_layers).map(|_| (minfo.n_heads, minfo.d_ff)).collect();
    for &l in &order {
        if env.model_time(&profile) <= budget {
            break;
        }
        profile[l] = (0, 0);
        for h in 0..minfo.n_heads {
            state.masks.kill_head(l, h);
        }
        for c in 0..minfo.d_ff {
            state.masks.kill_ffn_col(l, c);
        }
    }
    crate::train::rezero_dead(state, tinfo, minfo);
    Ok(profile)
}

/// Kwon et al.-style one-shot: diagonal saliencies + DP mask search +
/// single end reconstruction.
pub fn fisher_oneshot(
    state: &mut ModelState,
    minfo: &ModelInfo,
    tinfo: &TaskInfo,
    env: &InferenceEnv,
    hs: &Hessians,
    target: f64,
) -> Result<Vec<(usize, usize)>> {
    let dense = env.dense_time(minfo.n_layers);
    let budget = dense / target;
    // Per-module "databases" with diagonal-score priors and NO updates:
    // prior(level) = sqrt(Σ removed diag-scores / Σ all diag-scores).
    let mut modules = Vec::new();
    let mut removal_orders: Vec<(usize, bool, Vec<usize>)> = Vec::new();
    for l in 0..minfo.n_layers {
        for is_attn in [true, false] {
            let (w, h, g) = if is_attn {
                (state.attn_w_paper(tinfo, l)?, &hs.attn[l], minfo.d_head)
            } else {
                (state.fc_w_paper(tinfo, l)?, &hs.ffn[l], 1usize)
            };
            let n = w.cols() / g;
            // diag OBD score per structure: Σ_i Σ_{c∈S} w_ic² H_cc
            let mut scores = vec![0f64; n];
            for i in 0..w.rows() {
                let row = w.row(i);
                for j in 0..n {
                    for c in j * g..(j + 1) * g {
                        scores[j] += (row[c] as f64).powi(2) * (2.0 * h.at2(c, c) as f64);
                    }
                }
            }
            let total: f64 = scores.iter().sum::<f64>().max(1e-12);
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
            let ladder: Vec<usize> = if is_attn {
                (0..=n).rev().collect()
            } else {
                let mut v = vec![n];
                v.extend(minfo.ffn_ladder.iter().copied().filter(|&x| x < n));
                v
            };
            let mut options = Vec::new();
            for &rem in &ladder {
                let removed: f64 = order[..n - rem].iter().map(|&j| scores[j]).sum();
                options.push(LevelOpt {
                    remaining: rem,
                    cost: if is_attn { env.attn_time(rem) } else { env.mlp_time(rem) },
                    prior: (removed / total).sqrt(),
                });
            }
            modules.push(ModuleLevels { layer: l, is_attn, options });
            removal_orders.push((l, is_attn, order));
        }
    }
    let problem = SpdyProblem { modules, overhead: env.overhead() };
    let profile = spdy::solve_dp(&problem, &vec![1.0; problem.modules.len()], budget)
        .ok_or_else(|| anyhow::anyhow!("fisher: target infeasible"))?;
    // apply masks per chosen level, per removal order
    for ((m, &li), (l, is_attn, order)) in
        problem.modules.iter().zip(&profile).zip(&removal_orders)
    {
        let rem = m.options[li].remaining;
        let n = order.len();
        for &j in &order[..n - rem] {
            if *is_attn {
                state.masks.kill_head(*l, j);
            } else {
                state.masks.kill_ffn_col(*l, j);
            }
        }
    }
    crate::train::rezero_dead(state, tinfo, minfo);
    // single end reconstruction (least squares on kept columns)
    reconstruct_all(state, minfo, tinfo, hs)?;
    Ok(problem.as_layer_profile(&profile))
}

/// Least-squares re-fit of kept columns: Ŵ_K = (W H)[:,K] (H_KK)^{-1}.
/// This is Kwon's end-of-pipeline "mask tuning" analogue.
pub fn reconstruct_all(
    state: &mut ModelState,
    minfo: &ModelInfo,
    tinfo: &TaskInfo,
    hs: &Hessians,
) -> Result<()> {
    for l in 0..minfo.n_layers {
        // attention
        {
            let keep: Vec<usize> = (0..minfo.d_attn())
                .filter(|&c| state.masks.head_row(l)[c / minfo.d_head] > 0.0)
                .collect();
            if !keep.is_empty() && keep.len() < minfo.d_attn() {
                let w = state.attn_w_paper(tinfo, l)?;
                let new_w = reconstruct(&w, &hs.attn[l], &keep)?;
                let dead: Vec<usize> = (0..minfo.n_heads)
                    .filter(|&h| state.masks.head_row(l)[h] == 0.0)
                    .collect();
                state.set_attn_w_paper(tinfo, l, &new_w, &dead, minfo.d_head)?;
            }
        }
        // fc
        {
            let keep: Vec<usize> =
                (0..minfo.d_ff).filter(|&c| state.masks.ffn_row(l)[c] > 0.0).collect();
            if !keep.is_empty() && keep.len() < minfo.d_ff {
                let w = state.fc_w_paper(tinfo, l)?;
                let new_w = reconstruct(&w, &hs.ffn[l], &keep)?;
                let dead: Vec<usize> =
                    (0..minfo.d_ff).filter(|&c| state.masks.ffn_row(l)[c] == 0.0).collect();
                state.set_fc_w_paper(tinfo, l, &new_w, &dead)?;
            }
        }
    }
    Ok(())
}

fn reconstruct(w: &Tensor, h_acc: &Tensor, keep: &[usize]) -> Result<Tensor> {
    // H = 2 XX^T (+ small damp); solve Ŵ_K H_KK = (W H)_K
    let mut h = h_acc.clone();
    h.scale(2.0);
    let n = h.rows();
    let mean_diag = (0..n).map(|i| h.at2(i, i) as f64).sum::<f64>() / n as f64;
    h.add_diag((0.01 * mean_diag) as f32);
    let wh = w.matmul(&h); // [d_row, n]
    let hkk = h.gather_rows(keep).gather_cols(keep);
    let hkk_inv = linalg::spd_inverse(&hkk).map_err(anyhow::Error::msg)?;
    let whk = wh.gather_cols(keep); // [d_row, k]
    let w_new_k = whk.matmul(&hkk_inv); // [d_row, k]
    let mut out = Tensor::zeros(&w.shape);
    for i in 0..w.rows() {
        for (kk, &c) in keep.iter().enumerate() {
            out.data[i * w.cols() + c] = w_new_k.at2(i, kk);
        }
    }
    Ok(out)
}

/// DistilBERT/DistilGPT2-style student: drop every other layer.
pub fn half_depth_masks(state: &mut ModelState, minfo: &ModelInfo) {
    for l in (1..minfo.n_layers).step_by(2) {
        for h in 0..minfo.n_heads {
            state.masks.kill_head(l, h);
        }
        for c in 0..minfo.d_ff {
            state.masks.kill_ffn_col(l, c);
        }
    }
}

/// Well-Read-Students-style width scaling: keep `keep_heads` heads and
/// `keep_ff` FFN columns in every layer.
pub fn width_scaled_masks(state: &mut ModelState, minfo: &ModelInfo, keep_heads: usize, keep_ff: usize) {
    for l in 0..minfo.n_layers {
        for h in keep_heads..minfo.n_heads {
            state.masks.kill_head(l, h);
        }
        for c in keep_ff..minfo.d_ff {
            state.masks.kill_ffn_col(l, c);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::LatencyTable;
    use crate::models::tests_support::mini_state;

    fn env(minfo: &ModelInfo) -> InferenceEnv {
        InferenceEnv::measured(LatencyTable {
            model: minfo.name.clone(),
            device: "test".into(),
            regime: "throughput".into(),
            attn: (0..=minfo.n_heads).map(|h| h as f64 * 1e-3).collect(),
            mlp: vec![(minfo.d_ff, 4e-3), (minfo.d_ff / 2, 2e-3), (1, 1e-4), (0, 0.0)],
            overhead: 5e-4,
        })
        .unwrap()
    }

    #[test]
    fn magnitude_meets_budget() {
        let (minfo, tinfo, mut st) = mini_state();
        let e = env(&minfo);
        let prof = magnitude_for_speedup(&mut st, &minfo, &tinfo, &e, 2.0).unwrap();
        assert!(e.model_time(&prof) <= e.dense_time(minfo.n_layers) / 2.0 + 1e-9);
        // pruned structures' weights are zero
        let w = st.fc_w_paper(&tinfo, 0).unwrap();
        for c in 0..minfo.d_ff {
            if st.masks.ffn_row(0)[c] == 0.0 {
                for r in 0..w.rows() {
                    assert_eq!(w.at2(r, c), 0.0);
                }
            }
        }
    }

    #[test]
    fn layer_drop_drops_whole_layers() {
        let (minfo, tinfo, mut st) = mini_state();
        let e = env(&minfo);
        let prof = layer_drop_for_speedup(&mut st, &minfo, &tinfo, &e, 3.0).unwrap();
        for (l, &(h, f)) in prof.iter().enumerate() {
            assert!(
                (h == 0 && f == 0) || (h == minfo.n_heads && f == minfo.d_ff),
                "layer {l} partially dropped: {h},{f}"
            );
        }
    }

    #[test]
    fn reconstruct_reduces_error_vs_plain_masking() {
        use crate::util::prop::gen;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        let w = Tensor::from_vec(&[6, 10], gen::vec_f32(&mut rng, 60, 1.0));
        let h = Tensor::from_vec(&[10, 10], gen::spd(&mut rng, 10, 0.2));
        let keep: Vec<usize> = (0..7).collect();
        let rec = reconstruct(&w, &h, &keep).unwrap();
        let mut naive = w.clone();
        for i in 0..6 {
            for c in 7..10 {
                naive.data[i * 10 + c] = 0.0;
            }
        }
        let err = |cand: &Tensor| {
            let mut d = cand.clone();
            for i in 0..d.len() {
                d.data[i] -= w.data[i];
            }
            linalg::trace_whwt(&d, &h)
        };
        assert!(err(&rec) <= err(&naive) + 1e-9);
    }

    #[test]
    fn student_mask_shapes() {
        let (minfo, _tinfo, mut st) = mini_state();
        half_depth_masks(&mut st, &minfo);
        assert_eq!(st.masks.heads_alive(0), minfo.n_heads);
        if minfo.n_layers > 1 {
            assert_eq!(st.masks.heads_alive(1), 0);
        }
        let (minfo2, _t2, mut st2) = mini_state();
        width_scaled_masks(&mut st2, &minfo2, 1, 2);
        assert_eq!(st2.masks.heads_alive(0), 1);
        assert_eq!(st2.masks.ffn_alive(0), 2);
    }
}
