//! `ziplm` — launcher CLI for the ZipLM reproduction.
//!
//! Subcommands:
//!   train-teacher  --model M --task T [--epochs E]
//!   latency-table  --model M [--regime throughput|latency]
//!   prune-oneshot  --model M --task T --speedup S [--calib N]
//!   prune-gradual  --model M --task T --speedups 2,3,4 [--epochs E] [--session-dir D]
//!   eval           --ckpt path [--split dev|test]
//!   serve          --ckpt path [--batch B] [--wait-ms W]
//!   serve-family   --family runs/family_M_T/family.json [--requests N] [--pressure P] [--samples-out F]
//!   serve-fleet    --family runs/family_M_T/family.json [--workers N] [--crash P] [--seed S] [--samples-out F]
//!   adapt          --samples F (--env E|--family M) [--out plan.json] [--retarget-out env.json]
//!   experiment     <fig2|fig3|fig4|fig5|fig6|fig8|table1..table8|family|multienv|chaos|all> [--fast]
//!   repro          [--kick-tires] [--seed S] [--out DIR] [--precomputed DIR]
//!
//! Global flags: --artifacts DIR (default ./artifacts), --fast.
//!
//! The pruning subcommands drive [`ziplm::session::CompressionSession`];
//! `prune-gradual` checkpoints every stage under `--session-dir`
//! (default `runs/session_M_T`), so re-running the same command after a
//! crash resumes from the completed stages instead of recomputing;
//! `--retarget <env.json|slug>` re-certifies the same capture against
//! another environment (slugs resolve through the `--registry` dir,
//! default `envs/`) with zero Hessian recomputation. The serving
//! subcommands export their realized `BucketSample` telemetry with
//! `--samples-out`; `adapt` closes the loop offline (DESIGN.md §12):
//! drift-test the samples against the certifying env, fit a new env to
//! the observed traffic, and propose the next speedup targets from the
//! family frontier.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use ziplm::coordinator::{self, ServerCfg};
use ziplm::data;
use ziplm::env::{CostModel, Regime};
use ziplm::eval::evaluate;
use ziplm::exp::{self, ExpCtx};
use ziplm::latency;
use ziplm::models::ModelState;
use ziplm::pruner::PruneCfg;
use ziplm::runtime::Engine;
use ziplm::session::{stdout_progress, CompressionSession};
use ziplm::train::TrainCfg;
use ziplm::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
        std::process::exit(2);
    }
    let args = Args::parse(argv);
    let cmd = args.positional.first().cloned().unwrap_or_default();
    if let Err(e) = dispatch(&cmd, &args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "ziplm — inference-aware structured pruning (NeurIPS'23 reproduction)\n\
         usage: ziplm <train-teacher|latency-table|prune-oneshot|prune-gradual|compound|eval|serve|serve-family|serve-fleet|adapt|experiment|repro> [flags]\n\
         see README.md for the full flag reference"
    );
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "train-teacher" => train_teacher(args),
        "latency-table" => latency_table(args),
        "prune-oneshot" => prune_oneshot(args),
        "prune-gradual" => prune_gradual(args),
        "eval" => eval_cmd(args),
        "serve" => serve(args),
        "serve-family" => serve_family(args),
        "serve-fleet" => serve_fleet(args),
        "adapt" => adapt_cmd(args),
        "compound" => exp::run(&ctx(args)?, "compound"),
        "experiment" => experiment(args),
        "repro" => repro(args),
        _ => {
            usage();
            Err(anyhow!("unknown command `{cmd}`"))
        }
    }
}

fn ctx(args: &Args) -> Result<ExpCtx> {
    ExpCtx::new(&artifacts_dir(args), args.bool("fast"))
}

fn train_teacher(args: &Args) -> Result<()> {
    let ctx = ctx(args)?;
    let model = args.str_or("model", "bert-syn-base");
    let task = args.str_or("task", "sst2-syn");
    let ds = ctx.dataset(&model, &task);
    let st = ctx.teacher(&model, &task, &ds)?;
    let ev = evaluate(&ctx.engine, &st, &ds, "dev")?;
    println!("teacher {model}/{task}: dev metric {:.4} (ckpt in runs/)", ev.metric);
    Ok(())
}

fn latency_table(args: &Args) -> Result<()> {
    let engine = Engine::open(&artifacts_dir(args))?;
    let model = args.str_or("model", "bert-syn-base");
    let regime = args.str_or("regime", "throughput");
    let reps = args.usize_or("reps", 30);
    let t = latency::measure_cpu(&engine, &model, &regime, reps)?;
    println!("{}", t.render());
    let path = PathBuf::from("runs").join(format!("latency_{model}_{regime}.json"));
    t.save(&path)?;
    println!("saved to {}", path.display());
    Ok(())
}

fn prune_oneshot(args: &Args) -> Result<()> {
    let ctx = ctx(args)?;
    let model = args.str_or("model", "bert-syn-base");
    let task = args.str_or("task", "sst2-syn");
    let speedup = args.f64_or("speedup", 2.0);
    let ds = ctx.dataset(&model, &task);
    let mut st = ctx.teacher(&model, &task, &ds)?;
    let env = ctx.env(&model, Regime::parse(&args.str_or("regime", "throughput"))?)?;
    let mut cfg = PruneCfg { calib_samples: args.usize_or("calib", 256), ..Default::default() };
    cfg.spdy.iters = args.usize_or("spdy-iters", 120);
    let sess = CompressionSession::for_model(&ctx.engine, &model, &task)
        .with_env(env)
        .with_prune_cfg(cfg)
        .on_progress(stdout_progress())
        .open()?;
    let report = sess.oneshot(&mut st, &ds, speedup)?;
    let ev = evaluate(&ctx.engine, &st, &ds, "dev")?;
    println!(
        "one-shot {speedup}x: est={:.2}x dev-metric={:.4} profile={:?}",
        report.est_speedup, ev.metric, report.layer_profile
    );
    let default_out = format!("runs/oneshot_{model}_{task}_{speedup}x.zlm");
    let out = PathBuf::from(args.str_or("out", &default_out));
    st.save(&out)?;
    println!("saved {}", out.display());
    Ok(())
}

fn prune_gradual(args: &Args) -> Result<()> {
    let ctx = ctx(args)?;
    let model = args.str_or("model", "bert-syn-base");
    let task = args.str_or("task", "sst2-syn");
    let targets = args.f64_list("speedups", &[2.0, 3.0, 4.0]);
    let ds = ctx.dataset(&model, &task);
    let teacher = ctx.teacher(&model, &task, &ds)?;
    let env = ctx.env(&model, Regime::parse(&args.str_or("regime", "throughput"))?)?;
    let cfg = PruneCfg { calib_samples: args.usize_or("calib", 256), ..Default::default() };
    let kd = !ctx.engine.manifest.model(&model).causal;
    let tcfg = TrainCfg {
        lr: args.f64_or("lr", 5e-4),
        epochs: args.f64_or("epochs", 2.0),
        lambdas: if kd { [1.0, 0.5, 0.5] } else { [1.0, 0.0, 0.0] },
        ..Default::default()
    };
    // every stage checkpoints under the session dir: re-running this
    // command after a crash resumes instead of recomputing
    let session_dir =
        args.str_or("session-dir", &format!("runs/session_{model}_{task}"));
    let mut b = CompressionSession::for_model(&ctx.engine, &model, &task)
        .with_env(env.clone())
        .with_targets(&targets)
        .with_prune_cfg(cfg)
        .with_train_cfg(tcfg)
        .checkpoint_to(&session_dir)
        .on_progress(stdout_progress());
    if kd {
        b = b.with_teacher(teacher.params.clone());
    }
    let mut sess = b.open()?;
    // `--retarget <env.json|slug>`: re-certify this capture against
    // another environment — capture/database checkpoints are env-free,
    // so only the SPDY solve re-runs (zero Hessian recomputation)
    let registry =
        ziplm::session::registry::EnvRegistry::new(args.str_or("registry", "envs"));
    let cert_env = if let Some(name) = args.get("retarget") {
        let env2 = registry.resolve(name)?;
        println!("[session] retargeting onto {}", env2.describe());
        sess.retarget(env2.clone())?;
        env2
    } else {
        env
    };
    let stages = sess.run(teacher.clone(), &ds)?;
    let (computed, loaded) = sess.counters();
    println!("[session] {computed} artifact(s) computed, {loaded} resumed from {session_dir}");
    for s in &stages {
        let ev = evaluate(&ctx.engine, &s.state, &ds, "dev")?;
        println!(
            "{:>5.1}x  est={:.2}x  dev={:.4}  profile={:?}",
            s.report.target, s.report.est_speedup, ev.metric, s.state.masks.summary()
        );
        s.state.save(Path::new(&format!("runs/ziplm_{model}_{task}_{:.0}x.zlm", s.report.target)))?;
    }
    // record the whole certified family for `serve-family` (App. F)
    sess.emit_family(&teacher, &stages, &PathBuf::from(format!("runs/family_{model}_{task}")))?;
    // register the certifying env so the next run can `--retarget` it
    // by slug instead of a JSON path
    let slug = registry.register(&cert_env)?;
    println!("[registry] certifying env is `{slug}` in {}", registry.dir().display());
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let engine = Engine::open(&artifacts_dir(args))?;
    let ckpt = args.get("ckpt").ok_or_else(|| anyhow!("--ckpt required"))?;
    let st = ModelState::load(Path::new(ckpt))?;
    let info = engine.manifest.model(&st.model);
    let ds = data::load_sized(info, &st.task, 1024, 256);
    let split = args.str_or("split", "dev");
    let ev = evaluate(&engine, &st, &ds, &split)?;
    match ev.perplexity {
        Some(p) => println!("{ckpt}: {split} loss={:.4} ppl={p:.2} (n={})", ev.loss, ev.n),
        None => println!("{ckpt}: {split} metric={:.4} (n={})", ev.metric, ev.n),
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let ckpt = args.get("ckpt").ok_or_else(|| anyhow!("--ckpt required"))?;
    let st = ModelState::load(Path::new(ckpt))?;
    let model = st.model.clone();
    let task = st.task.clone();
    let cfg = ServerCfg {
        artifacts: artifacts_dir(args),
        max_batch: args.usize_or("batch", 8),
        max_wait: std::time::Duration::from_millis(args.u64_or("wait-ms", 2)),
    };
    // demo workload: submit n requests from the dev set, report stats
    let n = args.usize_or("requests", 64);
    let engine = Engine::open(&artifacts_dir(args))?;
    let info = engine.manifest.model(&model);
    let ds = data::load_sized(info, &task, 256, n.max(32));
    drop(engine);
    let handle = coordinator::start(cfg, st)?;
    let t0 = std::time::Instant::now();
    let mut latencies = Vec::new();
    for ex in ds.dev.iter().take(n) {
        let reply = handle.infer(ex.ids.clone())?;
        latencies.push(reply.latency.as_secs_f64());
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.total_cmp(b));
    let stats = handle.shutdown()?;
    println!(
        "served {n} requests ({} batches) in {wall:.2}s → {:.1} req/s, p50 {:.1}ms p95 {:.1}ms",
        stats.batches,
        n as f64 / wall,
        latencies[n / 2] * 1e3,
        latencies[(n as f64 * 0.95) as usize % n] * 1e3,
    );
    Ok(())
}

/// Serve a recorded model family behind the SLA-aware coordinator and
/// fire a mixed workload at it (paper App. F made operational).
fn serve_family(args: &Args) -> Result<()> {
    let man_path = args
        .get("family")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("runs/family_bert-syn-base_sst2-syn/family.json"));
    let fam = ziplm::models::family::FamilyManifest::load(&man_path)?;
    let base = man_path.parent().unwrap_or(Path::new(".")).to_path_buf();
    let members: Vec<(String, ziplm::models::ModelState)> =
        fam.load_states(&base)?.into_iter().map(|(m, st)| (m.tag, st)).collect();
    println!(
        "family {}/{}: {} members {:?}",
        fam.model,
        fam.task,
        members.len(),
        fam.members.iter().map(|m| m.tag.as_str()).collect::<Vec<_>>()
    );
    let ctx = ctx(args)?;
    // admission estimates come from the SAME env the family was
    // certified against: embedded in the manifest since the multi-env
    // sessions PR, so no re-measuring happens here. Pre-embedding
    // manifests fall back to a (cached) measurement for their regime.
    let env = match &fam.env {
        Some(e) => {
            println!("admission env loaded from manifest: {}", e.describe());
            e.clone()
        }
        None => {
            println!("manifest has no embedded env (pre-embedding file); measuring");
            ctx.env(&fam.model, Regime::parse(&fam.regime)?)?
        }
    };
    let minfo = ctx.engine.manifest.model(&fam.model).clone();
    let ds = ctx.dataset(&fam.model, &fam.task);
    // shaped batches + specialized executables at the bucket ladder the
    // manifest was certified under (empty ladder = generic-only)
    if !fam.buckets.is_empty() {
        println!("serving shape buckets: {:?}", fam.buckets);
    }
    let handle = ziplm::coordinator::family::start(
        ziplm::coordinator::family::FamilyCfg {
            artifacts: artifacts_dir(args),
            max_batch: args.usize_or("batch", 8),
            max_wait: std::time::Duration::from_millis(args.u64_or("wait-ms", 2)),
            pressure: args.usize_or("pressure", 64),
            buckets: ziplm::coordinator::family::BucketLadder::new(fam.buckets.clone()),
            specialized: None,
        },
        members,
        &env,
    )?;
    let n = args.usize_or("requests", 96);
    let bound =
        std::time::Duration::from_secs_f64(env.dense_time(minfo.n_layers) * 0.8);
    let min_speedup = fam
        .members
        .iter()
        .map(|m| m.est_speedup)
        .fold(1.0f64, f64::max)
        .min(2.0);
    let rows = exp::mixed_workload(&handle, &ds, n, bound, min_speedup)?;
    let stats = handle.shutdown()?;
    for r in ziplm::coordinator::family::summarize(&rows) {
        println!(
            "  [{:<12}] n={:<4} p50={:.1}ms p99={:.1}ms sla-hit={:.0}%",
            r.class,
            r.n,
            r.p50.as_secs_f64() * 1e3,
            r.p99.as_secs_f64() * 1e3,
            r.hit_rate * 100.0
        );
    }
    for bkt in &stats.per_bucket {
        println!(
            "  [bucket] {:>6} @ {}x{}{}: realized p50={:.1}ms certified={:.1}ms",
            bkt.member,
            bkt.batch,
            bkt.seq,
            if bkt.specialized { " spec" } else { "" },
            bkt.realized_p50.as_secs_f64() * 1e3,
            bkt.certified.as_secs_f64() * 1e3
        );
    }
    println!(
        "served {} requests / {} batches ({} coalesced); {} compile(s), {} cache hit(s); per-member {:?}",
        stats.requests,
        stats.batches,
        stats.coalesced_batches,
        stats.cache_builds,
        stats.cache_hits,
        stats.per_member
    );
    write_samples(args, &stats.samples)?;
    Ok(())
}

/// `--samples-out <path>`: export a serving run's realized
/// [`ziplm::coordinator::family::BucketSample`] stream as JSON — the
/// offline input `ziplm adapt` drift-tests (DESIGN.md §12).
fn write_samples(
    args: &Args,
    samples: &[ziplm::coordinator::family::BucketSample],
) -> Result<()> {
    let Some(path) = args.get("samples-out") else { return Ok(()) };
    let path = Path::new(path);
    if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir)?;
    }
    let json = ziplm::coordinator::family::samples_to_json(samples);
    std::fs::write(path, json.to_pretty() + "\n")?;
    println!("wrote {} realized sample(s) to {}", samples.len(), path.display());
    Ok(())
}

/// Serve a recorded family on the supervised simulated fleet under an
/// optional fault plan (DESIGN.md §10). Engine-free: members are priced
/// through the manifest's embedded certification env, so this runs
/// without artifacts — it is the CLI face of the chaos harness.
fn serve_fleet(args: &Args) -> Result<()> {
    use ziplm::coordinator::chaos::{self, TraceCfg, TraceClass};
    use ziplm::coordinator::family::BucketLadder;
    use ziplm::coordinator::fleet::{FleetCfg, FleetMember, RetryPolicy};
    use ziplm::runtime::{FaultPlan, FaultRates};

    let man_path = args
        .get("family")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("runs/family_bert-syn-base_sst2-syn/family.json"));
    let fam = ziplm::models::family::FamilyManifest::load(&man_path)?;
    let env = fam.env.clone().ok_or_else(|| {
        anyhow!(
            "manifest `{}` has no embedded env; serve-fleet is engine-free and \
             cannot measure one — re-run prune-gradual (or `experiment family`) \
             to emit a manifest with an env",
            man_path.display()
        )
    })?;
    let spec = fam.fleet.clone().unwrap_or_default();
    let workers = args.usize_or("workers", spec.workers.max(2));
    let cfg = FleetCfg {
        workers,
        skews: spec.skews,
        max_batch: args.usize_or("batch", 8),
        max_wait: std::time::Duration::from_millis(args.u64_or("wait-ms", 1)),
        queue_cap: args.usize_or("queue-cap", 64),
        retry: RetryPolicy {
            max_retries: args.usize_or("retries", 2) as u32,
            ..RetryPolicy::default()
        },
        buckets: BucketLadder::new(fam.buckets.clone()),
        ..FleetCfg::default()
    };
    let members: Vec<FleetMember> = fam
        .members
        .iter()
        .map(|m| FleetMember { tag: m.tag.clone(), profile: m.profile.clone() })
        .collect();
    println!(
        "fleet {}/{}: {} workers × {} members {:?}",
        fam.model,
        fam.task,
        workers,
        members.len(),
        fam.members.iter().map(|m| m.tag.as_str()).collect::<Vec<_>>()
    );
    let rates = FaultRates {
        crash: args.f64_or("crash", 0.0),
        compile_fail: args.f64_or("compile-fail", 0.0),
        slowdown: args.f64_or("slowdown", 0.0),
        slowdown_factor: args.f64_or("slowdown-factor", 3.0),
        nan_latency: 0.0,
    };
    let plan = FaultPlan::seeded(args.u64_or("seed", 0xC0FFEE), rates);
    let n_layers = members.first().map(|m| m.profile.len()).unwrap_or(1);
    let bound = std::time::Duration::from_secs_f64(env.dense_time(n_layers) * 0.8);
    let min_speedup = fam
        .members
        .iter()
        .map(|m| m.est_speedup)
        .fold(1.0f64, f64::max)
        .min(2.0);
    let trace = TraceCfg {
        requests: args.usize_or("requests", 128),
        seed: args.u64_or("trace-seed", 7),
        arrival_gap: std::time::Duration::from_micros(args.u64_or("gap-us", 50)),
        len_range: (4, 32),
        classes: vec![
            TraceClass::best_effort(2.0),
            TraceClass {
                class: "realtime".into(),
                weight: 1.0,
                max_latency: Some(bound),
                min_speedup: None,
            },
            TraceClass {
                class: "throughput".into(),
                weight: 1.0,
                max_latency: None,
                min_speedup: Some(min_speedup),
            },
        ],
    };
    let report = chaos::run_chaos(cfg, members, &env, plan, &trace)?;
    print!("{}", chaos::render_report(&report));
    // non-blocking drift surface: pure statistics over the samples the
    // supervisor already recorded, printed after the books balance
    let drift = report.stats.drift_report(&env, &ziplm::adapt::DriftCfg::default());
    println!(
        "  drift vs certifying env: latency {:.3} mass {:.3} overrun {:.0}% → {}",
        drift.latency_drift,
        drift.mass_shift,
        drift.overrun_rate * 100.0,
        if drift.drifted { "DRIFTED (run `ziplm adapt`)" } else { "within tolerance" }
    );
    write_samples(args, &report.stats.samples)?;
    if !report.balanced() {
        return Err(anyhow!(
            "request accounting does not balance ({} lost)",
            report.lost
        ));
    }
    Ok(())
}

/// `ziplm adapt` — offline traffic-adaptive retargeting (DESIGN.md
/// §12). Reads a recorded `--samples` stream (from any serving
/// surface's `--samples-out`), drift-tests it against the certifying
/// env (`--env <file|slug>`, or the env embedded in `--family`), fits
/// an env to the observed distribution when drifted, and proposes the
/// next speedup targets from the family frontier. Pure and engine-free:
/// same inputs, same plan, bit for bit.
fn adapt_cmd(args: &Args) -> Result<()> {
    use ziplm::adapt::{AdaptController, DriftCfg};
    use ziplm::coordinator::family::samples_from_json;
    use ziplm::models::family::FamilyManifest;
    use ziplm::session::registry::EnvRegistry;
    use ziplm::util::json::Json;

    let samples_path =
        args.get("samples").ok_or_else(|| anyhow!("--samples <file> required"))?;
    let text = std::fs::read_to_string(samples_path)?;
    let samples =
        samples_from_json(&Json::parse(&text).map_err(|e| anyhow!("{samples_path}: {e}"))?)?;

    // frontier evidence: every `--family` manifest (comma-separated)
    let mut manifests: Vec<FamilyManifest> = Vec::new();
    if let Some(list) = args.get("family") {
        for p in list.split(',').filter(|p| !p.trim().is_empty()) {
            manifests.push(FamilyManifest::load(Path::new(p.trim()))?);
        }
    }
    // certifying env: explicit --env wins; else the first manifest env
    let registry = EnvRegistry::new(args.str_or("registry", "envs"));
    let env = match args.get("env") {
        Some(name) => registry.resolve(name)?,
        None => manifests
            .iter()
            .find_map(|f| f.env.clone())
            .ok_or_else(|| anyhow!("--env <file|slug> or --family with an embedded env required"))?,
    };

    let ctl = AdaptController {
        cfg: DriftCfg {
            latency_ratio_tol: args.f64_or("latency-tol", 0.1),
            mass_shift_tol: args.f64_or("mass-tol", 0.25),
            min_requests: args.usize_or("min-requests", 16),
        },
        n_targets: args.usize_or("targets-n", 3),
    };
    let plan = ctl.plan(&samples, &env, &manifests)?;
    println!(
        "adapt: {} request(s) vs {} → latency drift {:.3} (tol {:.3}), mass shift {:.3} (tol {:.3}), overrun {:.0}%",
        plan.drift.requests,
        env.describe(),
        plan.drift.latency_drift,
        ctl.cfg.latency_ratio_tol,
        plan.drift.mass_shift,
        ctl.cfg.mass_shift_tol,
        plan.drift.overrun_rate * 100.0
    );
    for b in &plan.drift.per_bucket {
        println!(
            "  [{:>3}x{:<4}] share {:>5.1}%  realized/certified {:.3}",
            b.batch,
            b.seq,
            b.share * 100.0,
            b.latency_ratio
        );
    }
    match plan.knee {
        Some(k) => println!("frontier knee: {k:.2}x; proposed targets {:?}", plan.targets),
        None => println!("frontier too thin for a knee; proposed targets {:?}", plan.targets),
    }
    println!("action: {}", plan.action());
    if let Some(fitted) = &plan.fitted {
        println!("fitted env: {}", fitted.describe());
        if let Some(out) = args.get("retarget-out") {
            fitted.save(Path::new(out))?;
            let slug = registry.register(fitted)?;
            println!(
                "wrote {out}; registered as `{slug}` — run `ziplm prune-gradual --retarget {slug}` \
                 (or --retarget {out}) to re-certify with zero Hessian recomputation"
            );
        }
    }
    if let Some(out) = args.get("out") {
        std::fs::write(out, plan.to_json().to_pretty() + "\n")?;
        println!("wrote adapt plan to {out}");
    }
    Ok(())
}

fn experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow!("usage: ziplm experiment <id> [--fast]"))?;
    let ctx = ctx(args)?;
    exp::run(&ctx, &id)
}

/// `ziplm repro [--kick-tires] [--seed S] [--out DIR] [--precomputed DIR]`
///
/// Run the scenario-matrix reproduction harness (DESIGN.md §11).
/// `--kick-tires` is the engine-free deterministic subset golden-tested
/// in CI; without it the full matrix runs through the live session API
/// against `--artifacts`.
fn repro(args: &Args) -> Result<()> {
    let seed = args.u64_or("seed", exp::repro::DEFAULT_SEED);
    let out = PathBuf::from(args.str_or("out", "runs/repro"));
    let precomputed = PathBuf::from(args.str_or("precomputed", "tools/repro/precomputed"));
    let report = if args.bool("kick-tires") {
        exp::repro::run_kick_tires(seed, &precomputed)?
    } else {
        let ctx = ctx(args)?;
        exp::repro::run_full(&ctx, seed, &precomputed)?
    };
    let (ran, cached, errors) = report.cells.iter().fold((0, 0, 0), |(r, c, e), cell| {
        match cell.status {
            exp::repro::CellStatus::Ran => (r + 1, c, e),
            exp::repro::CellStatus::Cached => (r, c + 1, e),
            exp::repro::CellStatus::Error => (r, c, e + 1),
        }
    });
    println!(
        "repro ({}): {} cells — {ran} ran, {cached} cached, {errors} error; {} families",
        report.mode,
        report.cells.len(),
        report.families.len()
    );
    let (json_path, md_path) = exp::repro::write_report(&report, &out)?;
    println!("wrote {} and {}", json_path.display(), md_path.display());
    Ok(())
}
