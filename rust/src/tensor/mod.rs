//! Dense f32 tensor substrate: the native math used by the coordinator
//! (Hessian assembly, error priors, baselines, quantization, tests).
//!
//! This intentionally mirrors a small slice of ndarray: row-major
//! storage, shape vector, tiled GEMM with optional threading. The
//! model hot path runs through PJRT (runtime/), NOT through this — the
//! native mirror exists for Hessian/inverse work on the coordinator
//! side and to cross-check the HLO kernels.
//!
//! Kernel notes (the coordinator-side OBS loop lives or dies on these):
//!
//! * [`Tensor::matmul`] tiles over `KC`×`NC` blocks of B so the active
//!   panel stays cache-resident, with a quad-row inner kernel (four
//!   broadcast multiply-adds over contiguous B rows) routed through
//!   the explicit SIMD dispatch layer (`kernel::Dispatch::quad_axpy`,
//!   bit-identical across dispatch levels — DESIGN.md §14). Rows of C
//!   are split across scoped threads for large problems. Zero rows of
//!   A are skipped, which matters once pruning has zeroed columns.
//! * [`Tensor::transpose2`] is cache-blocked (32×32 tiles) so both the
//!   read and write sides stay within a few cache lines per tile.
//! * [`Tensor::matvec`] parallelizes over disjoint `&mut` output
//!   chunks via `parallel_for_slices_mut` — no raw-pointer writes.

pub mod linalg;

use crate::kernel::Dispatch;
use crate::util::threadpool::{enter_leaf_region, parallel_for_slices_mut, thread_budget};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap()
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols() + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let c = self.cols();
        self.data[i * c + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn transpose2(&self) -> Tensor {
        const BS: usize = 32; // tile edge: 32×32 f32 = 4 KiB, L1-resident
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[n, m]);
        for ib in (0..m).step_by(BS) {
            let iend = (ib + BS).min(m);
            for jb in (0..n).step_by(BS) {
                let jend = (jb + BS).min(n);
                for i in ib..iend {
                    for j in jb..jend {
                        out.data[j * m + i] = self.data[i * n + j];
                    }
                }
            }
        }
        out
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn add_diag(&mut self, v: f32) {
        let n = self.cols();
        assert_eq!(self.rows(), n);
        for i in 0..n {
            self.data[i * n + i] += v;
        }
    }

    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// C = A @ B (2-D, row-major, tiled, threaded for large sizes).
    ///
    /// The kernel walks B in `KC`×`NC` tiles so the active panel stays
    /// cache-resident across every row of A that a thread owns, and
    /// consumes A four scalars at a time (quad-row inner kernel:
    /// four broadcast multiply-adds over contiguous B row segments,
    /// dispatched to explicit SIMD — `kernel::Dispatch::quad_axpy`).
    /// All-zero A quads are skipped — after pruning, whole columns of
    /// W are zero and this turns into a cheap structural sparsity win.
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        const KC: usize = 64; // B-tile rows: 64×NC f32 panel ≈ 64 KiB
        const NC: usize = 256; // B-tile cols: C row segment ≈ 1 KiB
        let (m, k) = (self.rows(), self.cols());
        let (k2, n) = (b.rows(), b.cols());
        assert_eq!(k, k2, "matmul inner dim");
        let mut out = Tensor::zeros(&[m, n]);
        let a = &self.data;
        let bb = &b.data;
        let cdata = &mut out.data;
        // Captured BEFORE the scoped spawn below so a with_level
        // override on the calling thread reaches every worker.
        let kd = Dispatch::get();
        // `c` holds rows [rows.start, rows.end) of C, row-major.
        let work = |rows: std::ops::Range<usize>, c: &mut [f32]| {
            for jb in (0..n).step_by(NC) {
                let jend = (jb + NC).min(n);
                for kb in (0..k).step_by(KC) {
                    let kend = (kb + KC).min(k);
                    let kc = kend - kb;
                    let kq = kc - kc % 4;
                    for i in rows.clone() {
                        let arow = &a[i * k + kb..i * k + kend];
                        let cbase = (i - rows.start) * n;
                        let crow = &mut c[cbase + jb..cbase + jend];
                        let mut kk = 0;
                        while kk < kq {
                            let (a0, a1, a2, a3) =
                                (arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]);
                            if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                                let r = kb + kk;
                                let b0 = &bb[r * n + jb..r * n + jend];
                                let b1 = &bb[(r + 1) * n + jb..(r + 1) * n + jend];
                                let b2 = &bb[(r + 2) * n + jb..(r + 2) * n + jend];
                                let b3 = &bb[(r + 3) * n + jb..(r + 3) * n + jend];
                                kd.quad_axpy(crow, [a0, a1, a2, a3], b0, b1, b2, b3);
                            }
                            kk += 4;
                        }
                        for kk in kq..kc {
                            let aik = arow[kk];
                            if aik == 0.0 {
                                continue;
                            }
                            let r = kb + kk;
                            let brow = &bb[r * n + jb..r * n + jend];
                            kd.axpy(crow, aik, brow);
                        }
                    }
                }
            }
        };
        // inline for small problems or when the enclosing parallel
        // region (e.g. a per-module database build) left no budget
        let budget = thread_budget();
        if m * n * k < 64 * 64 * 64 || budget <= 1 {
            work(0..m, cdata);
        } else {
            // parallel over row chunks, each into its own slice
            let chunks: Vec<std::ops::Range<usize>> = {
                let per = m.div_ceil(budget);
                (0..m).step_by(per.max(1)).map(|s| s..(s + per).min(m)).collect()
            };
            let mut slices: Vec<&mut [f32]> = Vec::new();
            let mut rest = cdata.as_mut_slice();
            for r in &chunks {
                let (head, tail) = rest.split_at_mut((r.end - r.start) * n);
                slices.push(head);
                rest = tail;
            }
            std::thread::scope(|s| {
                for (r, slice) in chunks.iter().zip(slices.into_iter()) {
                    let r = r.clone();
                    s.spawn(move || {
                        enter_leaf_region();
                        work(r, slice)
                    });
                }
            });
        }
        out
    }

    /// y = A @ x for vector x. Parallel rows write through disjoint
    /// `&mut` output chunks — safety by construction, no raw pointers.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let (m, k) = (self.rows(), self.cols());
        assert_eq!(k, x.len());
        let mut y = vec![0f32; m];
        parallel_for_slices_mut(&mut y, 256, |start, ys| {
            for (off, yi) in ys.iter_mut().enumerate() {
                let i = start + off;
                let row = &self.data[i * k..(i + 1) * k];
                let mut s = 0f32;
                for (a, b) in row.iter().zip(x) {
                    s += a * b;
                }
                *yi = s;
            }
        });
        y
    }

    /// Gather columns into a new matrix.
    pub fn gather_cols(&self, cols: &[usize]) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[m, cols.len()]);
        for i in 0..m {
            for (jj, &j) in cols.iter().enumerate() {
                debug_assert!(j < n);
                out.data[i * cols.len() + jj] = self.data[i * n + j];
            }
        }
        out
    }

    pub fn gather_rows(&self, rows: &[usize]) -> Tensor {
        let n = self.cols();
        let mut out = Tensor::zeros(&[rows.len(), n]);
        for (ii, &i) in rows.iter().enumerate() {
            out.row_mut(ii).copy_from_slice(self.row(i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randt(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| rng.normal_f32(1.0)).collect())
    }

    #[test]
    fn matmul_small_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive_large() {
        let mut rng = Rng::new(1);
        let a = randt(&mut rng, &[70, 90]);
        let b = randt(&mut rng, &[90, 110]);
        let c = a.matmul(&b);
        // naive check on a few random entries
        for _ in 0..50 {
            let i = rng.below(70);
            let j = rng.below(110);
            let mut s = 0f64;
            for k in 0..90 {
                s += a.at2(i, k) as f64 * b.at2(k, j) as f64;
            }
            assert!((c.at2(i, j) as f64 - s).abs() < 1e-3, "({i},{j})");
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(2);
        let a = randt(&mut rng, &[33, 47]);
        let x: Vec<f32> = (0..47).map(|_| rng.normal_f32(1.0)).collect();
        let y = a.matvec(&x);
        let xm = Tensor::from_vec(&[47, 1], x);
        let ym = a.matmul(&xm);
        for i in 0..33 {
            assert!((y[i] - ym.data[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = randt(&mut rng, &[5, 9]);
        assert_eq!(a.transpose2().transpose2(), a);
        // non-multiple-of-tile dims exercise the blocked edges
        let b = randt(&mut rng, &[70, 45]);
        assert_eq!(b.transpose2().transpose2(), b);
        let bt = b.transpose2();
        for i in 0..70 {
            for j in 0..45 {
                assert_eq!(bt.at2(j, i), b.at2(i, j));
            }
        }
    }

    #[test]
    fn matmul_tile_boundaries_and_zero_quads() {
        // k not a multiple of 4, n larger than one j-tile, plus whole
        // zero column-quads of A (the pruned-weight case).
        let mut rng = Rng::new(4);
        let mut a = randt(&mut rng, &[40, 130]);
        for i in 0..40 {
            for kk in 64..72 {
                a.set2(i, kk, 0.0);
            }
        }
        let b = randt(&mut rng, &[130, 300]);
        let c = a.matmul(&b);
        for _ in 0..40 {
            let i = rng.below(40);
            let j = rng.below(300);
            let mut s = 0f64;
            for kk in 0..130 {
                s += a.at2(i, kk) as f64 * b.at2(kk, j) as f64;
            }
            assert!((c.at2(i, j) as f64 - s).abs() < 2e-3, "({i},{j})");
        }
    }

    #[test]
    fn gather_cols_rows() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let g = a.gather_cols(&[2, 0]);
        assert_eq!(g.data, vec![3., 1., 6., 4.]);
        let r = a.gather_rows(&[1]);
        assert_eq!(r.data, vec![4., 5., 6.]);
    }

    #[test]
    fn eye_and_diag() {
        let mut t = Tensor::eye(3);
        t.add_diag(2.0);
        assert_eq!(t.at2(1, 1), 3.0);
        assert_eq!(t.at2(0, 1), 0.0);
    }
}
