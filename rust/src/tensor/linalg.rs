//! Native dense linear algebra: Cholesky, triangular solves, SPD
//! inverse, and the structured-OBS primitives' Rust mirror.
//!
//! Used by the coordinator for (a) building H^{-1} = (2XX^T + λI)^{-1}
//! once per layer before pruning, (b) error priors p_s = ||ŴX − WX||/
//! ||WX|| via trace identities, and (c) cross-checking the HLO kernels
//! in tests. All SPD matrices here are damped Hessians, so unpivoted
//! Cholesky is safe.
//!
//! Two implementations of the SPD inverse live here:
//!
//! * [`spd_inverse`] — the fast path. Per unit-vector column e_j the
//!   forward solve starts at row j (everything above is structurally
//!   zero), the backward solve stops at row j, and the strictly-upper
//!   triangle is mirrored from the lower one (A^{-1} is symmetric).
//!   ~3× fewer flops than the naive two-full-solves-per-column loop,
//!   and the backward solve reads L^T row-contiguously.
//! * [`spd_inverse_ref`] — the original reference loop, kept for
//!   property tests and before/after benchmarks.

use super::Tensor;
use crate::kernel::Dispatch;
use crate::util::threadpool::parallel_for_slices_mut;

/// Cholesky factor L (lower) of SPD `a`, in place semantics: returns L.
/// Inner dots run over contiguous row slices of L.
pub fn cholesky(a: &Tensor) -> Result<Tensor, String> {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut l = Tensor::zeros(&[n, n]);
    for j in 0..n {
        let mut d = a.at2(j, j);
        {
            let lj = &l.data[j * n..j * n + j];
            for v in lj {
                d -= v * v;
            }
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(format!("cholesky: non-PD at pivot {j} (d={d})"));
        }
        let d = d.sqrt();
        l.set2(j, j, d);
        for i in (j + 1)..n {
            let s = {
                let li = &l.data[i * n..i * n + j];
                let lj = &l.data[j * n..j * n + j];
                let mut s = a.at2(i, j);
                for (x, y) in li.iter().zip(lj) {
                    s -= x * y;
                }
                s
            };
            l.set2(i, j, s / d);
        }
    }
    Ok(l)
}

/// Solve L y = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Tensor, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    let mut y = vec![0f32; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l.at2(i, k) * y[k];
        }
        y[i] = s / l.at2(i, i);
    }
    y
}

/// Solve L^T x = y (backward substitution).
pub fn solve_upper_t(l: &Tensor, y: &[f32]) -> Vec<f32> {
    let n = l.rows();
    let mut x = vec![0f32; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l.at2(k, i) * x[k];
        }
        x[i] = s / l.at2(i, i);
    }
    x
}

/// Flop target per worker chunk of the threaded column sweep: below
/// this the spawn overhead beats the win, so `parallel_for_slices_mut`
/// degenerates to the inline loop (small matrices, or any nested
/// parallel region where the thread budget is already spent).
const SPD_PAR_CHUNK_FLOPS: f64 = 250_000.0;

/// SPD inverse via Cholesky. Fast path: per unit-vector column the
/// forward solve skips the structural zeros above row j, the backward
/// solve stops once rows < j are no longer needed, and the upper
/// triangle is mirrored from the lower (the inverse is symmetric) —
/// ~3× fewer flops than [`spd_inverse_ref`].
///
/// The per-column solves are independent given L / L^T, so they fan
/// out across the pool via [`parallel_for_slices_mut`] in chunks of
/// whole columns (each slice element IS one column buffer, so chunk
/// boundaries can never split a column). Column j costs ~(n−j)² flops
/// — triangular — while the primitive cuts uniform-count chunks, so
/// elements are laid out in the interleaved order 0, n−1, 1, n−2, …:
/// every contiguous chunk then alternates expensive and cheap columns
/// and carries near-equal work. The fan-out is nesting-aware exactly
/// like the OBS score sweep: inside a `parallel_tasks` worker the
/// thread budget is 1 and the sweep runs inline, bit-identical to the
/// serial path. The O(n²) mirror stays serial — noise next to the
/// O(n³) solves.
///
/// When the [`Dispatch`] level is vector (SSE2/AVX2) the sweep
/// processes `lanes` consecutive columns per step through
/// [`Dispatch::spd_solve_lanes`]: lane `l` runs column `j0+l`'s
/// forward/backward solve in the scalar accumulation order, so the
/// result is bit-identical to the scalar sweep (DESIGN.md §14) — only
/// the grouping of the interleaved work order changes.
pub fn spd_inverse(a: &Tensor) -> Result<Tensor, String> {
    let n = a.rows();
    let l = cholesky(a)?;
    let lt = l.transpose2(); // row-contiguous access for the backward solve
    let ld = &l.data;
    let ltd = &lt.data;
    let kd = Dispatch::get();
    let mut inv = Tensor::zeros(&[n, n]);
    // per-column work ≈ (n-j)² MACs, averaging n²/3 over the sweep
    let per_col = (n as f64) * (n as f64) / 3.0;
    let min_cols = ((SPD_PAR_CHUNK_FLOPS / per_col.max(1.0)).ceil() as usize).max(1);
    if kd.lanes() > 1 {
        // Vector path: one lane-block of `lanes` consecutive columns
        // per sweep element; groups interleave front/back for balance.
        let lanes = kd.lanes();
        let ngroups = n.div_ceil(lanes);
        let grp_of = |k: usize| if k % 2 == 0 { k / 2 } else { ngroups - 1 - k / 2 };
        let min_groups = min_cols.div_ceil(lanes).max(1);
        let mut groups: Vec<Vec<f32>> = vec![Vec::new(); ngroups];
        parallel_for_slices_mut(&mut groups, min_groups, |start, chunk| {
            // Reused across groups without re-zeroing: the solves write
            // every row ≥ j0 before reading it and never touch rows
            // < j0, which the scatter below never reads either.
            let mut y = vec![0f32; n * lanes];
            let mut x = vec![0f32; n * lanes];
            for (ci, xbuf) in chunk.iter_mut().enumerate() {
                let j0 = grp_of(start + ci) * lanes;
                kd.spd_solve_lanes(ld, ltd, n, j0, &mut y, &mut x);
                *xbuf = x[j0 * lanes..n * lanes].to_vec();
            }
        });
        for (k, xbuf) in groups.iter().enumerate() {
            let j0 = grp_of(k) * lanes;
            for l in 0..lanes.min(n - j0) {
                let j = j0 + l;
                for i in j..n {
                    let v = xbuf[(i - j0) * lanes + l];
                    inv.data[i * n + j] = v;
                    inv.data[j * n + i] = v;
                }
            }
        }
        return Ok(inv);
    }
    // element k ↔ column: front half on even k, back half on odd k
    let col_of = |k: usize| if k % 2 == 0 { k / 2 } else { n - 1 - k / 2 };
    let mut cols: Vec<Vec<f32>> = vec![Vec::new(); n];
    parallel_for_slices_mut(&mut cols, min_cols, |start, chunk| {
        let mut y = vec![0f32; n];
        let mut x = vec![0f32; n];
        for (ci, col) in chunk.iter_mut().enumerate() {
            let j = col_of(start + ci);
            // forward: L y = e_j; y[i < j] = 0 structurally, so start at j.
            y[j] = 1.0 / ld[j * n + j];
            for i in (j + 1)..n {
                let li = &ld[i * n + j..i * n + i]; // L[i, j..i]
                let mut s = 0f32;
                for (v, yk) in li.iter().zip(&y[j..i]) {
                    s += v * yk;
                }
                y[i] = -s / ld[i * n + i];
            }
            // backward: L^T x = y; only x[i ≥ j] is needed for this
            // column, and x[i] depends only on x[k > i], so stop at i = j.
            for i in (j..n).rev() {
                let row = &ltd[i * n + i + 1..i * n + n]; // L^T[i, i+1..] = L[i+1.., i]
                let mut s = y[i];
                for (v, xk) in row.iter().zip(&x[i + 1..n]) {
                    s -= v * xk;
                }
                x[i] = s / ld[i * n + i];
            }
            *col = x[j..n].to_vec();
        }
    });
    // column col_of(k) of the inverse, mirrored across the diagonal.
    for (k, col) in cols.iter().enumerate() {
        let j = col_of(k);
        for (o, &v) in col.iter().enumerate() {
            let i = j + o;
            inv.data[i * n + j] = v;
            inv.data[j * n + i] = v;
        }
    }
    Ok(inv)
}

/// Reference SPD inverse (solve both triangles fully for each unit
/// vector). Kept as the equivalence oracle for [`spd_inverse`] in
/// property tests and as the "before" entry in the hot-path benches.
pub fn spd_inverse_ref(a: &Tensor) -> Result<Tensor, String> {
    let n = a.rows();
    let l = cholesky(a)?;
    let mut inv = Tensor::zeros(&[n, n]);
    let mut e = vec![0f32; n];
    for j in 0..n {
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_upper_t(&l, &y);
        for i in 0..n {
            inv.set2(i, j, x[i]);
        }
        e[j] = 0.0;
    }
    Ok(inv)
}

/// Small general inverse via Gauss-Jordan with partial pivoting (used
/// for g×g inverse-Hessian blocks in the native OBS mirror).
pub fn gj_inverse(a: &Tensor) -> Result<Tensor, String> {
    let n = a.rows();
    let mut m = a.data.clone();
    let mut inv = Tensor::eye(n);
    gj_inverse_flat(&mut m, &mut inv.data, n)?;
    Ok(inv)
}

/// Allocation-free core of [`gj_inverse`], for callers that batch many
/// small blocks (the structured-OBS score path inverts one g×g block
/// per active structure). `m` is destroyed; `inv` must hold the n×n
/// identity on entry and receives the inverse.
pub fn gj_inverse_flat(m: &mut [f32], inv: &mut [f32], n: usize) -> Result<(), String> {
    assert_eq!(m.len(), n * n);
    assert_eq!(inv.len(), n * n);
    for k in 0..n {
        // pivot
        let mut p = k;
        for i in (k + 1)..n {
            if m[i * n + k].abs() > m[p * n + k].abs() {
                p = i;
            }
        }
        if m[p * n + k].abs() < 1e-20 {
            return Err(format!("gj_inverse: singular at {k}"));
        }
        if p != k {
            for j in 0..n {
                m.swap(k * n + j, p * n + j);
                inv.swap(k * n + j, p * n + j);
            }
        }
        let piv = m[k * n + k];
        for j in 0..n {
            m[k * n + j] /= piv;
            inv[k * n + j] /= piv;
        }
        for i in 0..n {
            if i == k {
                continue;
            }
            let f = m[i * n + k];
            if f == 0.0 {
                continue;
            }
            for j in 0..n {
                m[i * n + j] -= f * m[k * n + j];
                inv[i * n + j] -= f * inv[k * n + j];
            }
        }
    }
    Ok(())
}

/// trace(W H W^T) = Σ_i w_i H w_i^T — the squared output norm ||W X||_F^2
/// when H = X X^T. Used for the SPDY error prior denominators.
pub fn trace_whwt(w: &Tensor, h: &Tensor) -> f64 {
    let (_m, n) = (w.rows(), w.cols());
    assert_eq!(h.rows(), n);
    let mut total = 0f64;
    for i in 0..w.rows() {
        let wi = w.row(i);
        let hw = h.matvec(wi);
        let mut s = 0f64;
        for (a, b) in wi.iter().zip(&hw) {
            s += (*a as f64) * (*b as f64);
        }
        total += s;
    }
    total
}

/// Symmetric eigendecomposition by cyclic Jacobi rotations, f64
/// accumulation throughout. Returns the eigenvalues in descending
/// order and the matching eigenvectors as COLUMNS of the returned
/// tensor. Sized for the small Gram matrices of the low-rank choice
/// axis (d_model × d_model); O(n³) per sweep, a handful of sweeps to
/// converge on symmetric input.
pub fn sym_eig(a: &Tensor) -> Result<(Vec<f32>, Tensor), String> {
    let n = a.rows();
    if n != a.cols() {
        return Err(format!("sym_eig: non-square {}x{}", a.rows(), a.cols()));
    }
    if n == 0 {
        return Err("sym_eig: empty matrix".into());
    }
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let scale: f64 = m.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-30);
    for _sweep in 0..60 {
        let mut off = 0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[p * n + q] * m[p * n + q];
            }
        }
        if off.sqrt() <= 1e-12 * scale {
            break;
        }
        for p in 0..n.saturating_sub(1) {
            for q in (p + 1)..n {
                let apq = m[p * n + q];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rows/cols p and q of the (symmetric) working matrix
                for k in 0..n {
                    let akp = m[k * n + p];
                    let akq = m[k * n + q];
                    m[k * n + p] = c * akp - s * akq;
                    m[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[p * n + k];
                    let aqk = m[q * n + k];
                    m[p * n + k] = c * apk - s * aqk;
                    m[q * n + k] = s * apk + c * aqk;
                }
                // accumulate the rotation into the eigenvector columns
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| {
        let (a, b) = (m[i * n + i], m[j * n + j]);
        b.partial_cmp(&a).unwrap_or(std::cmp::Ordering::Equal)
    });
    let vals: Vec<f32> = order.iter().map(|&i| m[i * n + i] as f32).collect();
    let mut vecs = Tensor::zeros(&[n, n]);
    for (col, &i) in order.iter().enumerate() {
        for k in 0..n {
            vecs.set2(k, col, v[k * n + i] as f32);
        }
    }
    Ok((vals, vecs))
}

/// Best rank-`rank` approximation of `w` (Eckart–Young in the row
/// space): W_r = U_r U_rᵀ W, where U_r spans the top eigenvectors of
/// the Gram matrix G = W Wᵀ — equivalent to truncated SVD without
/// forming the (much larger) column-space factor. The Frobenius
/// residual ||W − W_r||²_F = Σ_{i>r} λ_i(G) is the loss score of the
/// low-rank choice axis (DESIGN.md §13).
pub fn low_rank_approx(w: &Tensor, rank: usize) -> Result<Tensor, String> {
    let m = w.rows();
    if m == 0 || w.cols() == 0 {
        return Err(format!("low_rank_approx: degenerate {}x{}", w.rows(), w.cols()));
    }
    if rank >= m {
        return Ok(w.clone());
    }
    if rank == 0 {
        return Ok(Tensor::zeros(&[m, w.cols()]));
    }
    let g = w.matmul(&w.transpose2());
    let (_vals, u) = sym_eig(&g)?;
    // projector P = U_r U_rᵀ onto the top-rank eigenspace, in f64
    let mut proj = Tensor::zeros(&[m, m]);
    for i in 0..m {
        for j in 0..m {
            let mut s = 0f64;
            for r in 0..rank {
                s += (u.at2(i, r) as f64) * (u.at2(j, r) as f64);
            }
            proj.set2(i, j, s as f32);
        }
    }
    Ok(proj.matmul(w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{gen, Prop};
    use crate::util::rng::Rng;

    fn spd_t(rng: &mut Rng, n: usize) -> Tensor {
        Tensor::from_vec(&[n, n], gen::spd(rng, n, 0.5))
    }

    #[test]
    fn cholesky_reconstructs() {
        Prop::new(20).check_msg(
            "LL^T = A",
            |r| { let n = 1 + r.below(24); spd_t(r, n) },
            |a| {
                let l = cholesky(a).map_err(|e| e)?;
                let rec = l.matmul(&l.transpose2());
                let d = rec.max_abs_diff(a);
                if d < 1e-2 * a.rows() as f32 {
                    Ok(())
                } else {
                    Err(format!("max diff {d}"))
                }
            },
        );
    }

    #[test]
    fn spd_inverse_is_inverse() {
        Prop::new(15).check_msg(
            "A A^{-1} = I",
            |r| { let n = 2 + r.below(20); spd_t(r, n) },
            |a| {
                let inv = spd_inverse(a)?;
                let prod = a.matmul(&inv);
                let d = prod.max_abs_diff(&Tensor::eye(a.rows()));
                if d < 5e-3 {
                    Ok(())
                } else {
                    Err(format!("residual {d}"))
                }
            },
        );
    }

    #[test]
    fn fast_spd_inverse_matches_ref_and_is_symmetric() {
        // mostly small instances (inline path) plus an occasional
        // 120..168 one, where the column sweep's chunking gate opens on
        // multi-core runners — both paths must agree with the reference
        Prop::new(15).check_msg(
            "spd_inverse == spd_inverse_ref, exactly symmetric",
            |r| {
                let n = if r.f64() < 0.2 { 120 + r.below(48) } else { 2 + r.below(24) };
                spd_t(r, n)
            },
            |a| {
                let f = spd_inverse(a)?;
                let g = spd_inverse_ref(a)?;
                let d = f.max_abs_diff(&g);
                // f32 rounding grows with n; scale the bound accordingly
                let tol = 1e-3 * (1.0 + a.rows() as f32 / 32.0);
                if d > tol {
                    return Err(format!("fast vs ref diff {d} (tol {tol})"));
                }
                let n = a.rows();
                for i in 0..n {
                    for j in 0..n {
                        if f.at2(i, j) != f.at2(j, i) {
                            return Err(format!("asymmetric at ({i},{j})"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn threaded_spd_inverse_matches_ref_at_chunking_sizes() {
        // Deterministic sizes bracketing the parallel gate: 144 gives
        // ~3 column chunks on a multi-core box (and runs inline on a
        // 1-core box or inside a parallel region — same arithmetic
        // either way, so the comparison is toolchain-independent).
        let mut rng = Rng::new(11);
        for n in [96usize, 144] {
            let a = spd_t(&mut rng, n);
            let f = spd_inverse(&a).unwrap();
            let g = spd_inverse_ref(&a).unwrap();
            assert!(f.max_abs_diff(&g) < 1e-2, "n={n} diff {}", f.max_abs_diff(&g));
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(f.at2(i, j), f.at2(j, i), "asymmetric at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn threaded_spd_inverse_inside_parallel_region_runs_inline_and_matches() {
        // nesting-awareness: inside a parallel_tasks worker the budget
        // is 1, the sweep must degrade to the inline loop and still be
        // correct (this is the score-sweep contract the satellite asks
        // spd_inverse to share)
        use crate::util::threadpool::parallel_tasks;
        let serial: Vec<Tensor> = {
            let mut rng = Rng::new(23);
            (0..2).map(|_| spd_t(&mut rng, 100)).collect()
        };
        let expect: Vec<Tensor> = serial.iter().map(|a| spd_inverse_ref(a).unwrap()).collect();
        let got = parallel_tasks(serial.len(), |i| spd_inverse(&serial[i]).unwrap());
        for (f, g) in got.iter().zip(&expect) {
            assert!(f.max_abs_diff(g) < 1e-2, "diff {}", f.max_abs_diff(g));
        }
    }

    #[test]
    fn gj_matches_spd_inverse() {
        let mut rng = Rng::new(5);
        let a = spd_t(&mut rng, 12);
        let i1 = spd_inverse(&a).unwrap();
        let i2 = gj_inverse(&a).unwrap();
        assert!(i1.max_abs_diff(&i2) < 1e-3);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 2.0, 1.0]); // eigvals 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn trace_identity_matches_direct() {
        // ||W X||_F^2 == trace(W (X X^T) W^T)
        let mut rng = Rng::new(7);
        let (m, n, s) = (6, 9, 30);
        let w = Tensor::from_vec(&[m, n], gen::vec_f32(&mut rng, m * n, 1.0));
        let x = Tensor::from_vec(&[n, s], gen::vec_f32(&mut rng, n * s, 1.0));
        let h = x.matmul(&x.transpose2());
        let wx = w.matmul(&x);
        let direct = wx.frob_sq();
        let via_trace = trace_whwt(&w, &h);
        assert!((direct - via_trace).abs() / direct < 1e-4);
    }

    #[test]
    fn sym_eig_reconstructs_and_orders() {
        Prop::new(12).check_msg(
            "V diag(λ) Vᵀ = A, λ descending, V orthonormal",
            |r| {
                let n = 2 + r.below(16);
                spd_t(r, n)
            },
            |a| {
                let n = a.rows();
                let (vals, v) = sym_eig(a)?;
                for w in vals.windows(2) {
                    if w[0] < w[1] - 1e-4 {
                        return Err(format!("eigvals not descending: {vals:?}"));
                    }
                }
                // orthonormal columns
                let vtv = v.transpose2().matmul(&v);
                let d = vtv.max_abs_diff(&Tensor::eye(n));
                if d > 1e-3 {
                    return Err(format!("VᵀV residual {d}"));
                }
                // reconstruction
                let mut vl = v.clone();
                for i in 0..n {
                    for j in 0..n {
                        vl.set2(i, j, v.at2(i, j) * vals[j]);
                    }
                }
                let rec = vl.matmul(&v.transpose2());
                let d = rec.max_abs_diff(a);
                if d < 1e-2 * n as f32 {
                    Ok(())
                } else {
                    Err(format!("reconstruction diff {d}"))
                }
            },
        );
    }

    #[test]
    fn low_rank_approx_is_eckart_young_on_known_instance() {
        // rank-2 matrix: rows 2 and 3 are multiples of rows 0 and 1
        let w = Tensor::from_vec(
            &[4, 3],
            vec![1.0, 0.0, 2.0, 0.0, 3.0, 1.0, 2.0, 0.0, 4.0, 0.0, 6.0, 2.0],
        );
        let r2 = low_rank_approx(&w, 2).unwrap();
        assert!(r2.max_abs_diff(&w) < 1e-4, "rank-2 must be exact: {}", r2.max_abs_diff(&w));
        // rank-1 residual equals the discarded Gram eigenvalue
        let g = w.matmul(&w.transpose2());
        let (vals, _) = sym_eig(&g).unwrap();
        let r1 = low_rank_approx(&w, 1).unwrap();
        let mut diff = r1.clone();
        for i in 0..w.rows() {
            for j in 0..w.cols() {
                diff.set2(i, j, r1.at2(i, j) - w.at2(i, j));
            }
        }
        let resid = diff.frob_sq();
        assert!(
            (resid - vals[1] as f64).abs() < 1e-3 * vals[0] as f64,
            "residual {resid} vs λ₂ {}",
            vals[1]
        );
        // boundary ranks
        assert!(low_rank_approx(&w, 4).unwrap().max_abs_diff(&w) == 0.0);
        assert_eq!(low_rank_approx(&w, 0).unwrap().frob_sq(), 0.0);
    }
}
